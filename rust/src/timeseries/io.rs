//! Tiny CSV reader/writer for time-series columns (no external crates).
//!
//! Format: optional header row, comma-separated numeric columns. Used by
//! `parccm sweep --input series.csv` and the examples to persist runs.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use crate::util::error::{bail, Context as _, Result};

/// A named set of equal-length columns.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Table {
    pub names: Vec<String>,
    pub columns: Vec<Vec<f32>>,
}

impl Table {
    pub fn new(names: Vec<String>, columns: Vec<Vec<f32>>) -> Result<Table> {
        if names.len() != columns.len() {
            bail!("{} names for {} columns", names.len(), columns.len());
        }
        if let Some(first) = columns.first() {
            if columns.iter().any(|c| c.len() != first.len()) {
                bail!("ragged columns");
            }
        }
        Ok(Table { names, columns })
    }

    pub fn len(&self) -> usize {
        self.columns.first().map(|c| c.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Column by name.
    pub fn column(&self, name: &str) -> Option<&[f32]> {
        self.names
            .iter()
            .position(|n| n == name)
            .map(|i| self.columns[i].as_slice())
    }
}

/// Parse a CSV file. If the first row has any non-numeric cell it is
/// treated as a header; otherwise columns are named `c0`, `c1`, ...
pub fn read_csv(path: impl AsRef<Path>) -> Result<Table> {
    let text = fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse_csv(&text)
}

/// Parse CSV text (see [`read_csv`]).
pub fn parse_csv(text: &str) -> Result<Table> {
    let mut lines = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'));
    let first = match lines.next() {
        Some(l) => l,
        None => return Ok(Table::default()),
    };
    let first_cells: Vec<&str> = first.split(',').map(str::trim).collect();
    let ncols = first_cells.len();
    let is_header = first_cells.iter().any(|c| c.parse::<f32>().is_err());
    let names: Vec<String> = if is_header {
        first_cells.iter().map(|s| s.to_string()).collect()
    } else {
        (0..ncols).map(|i| format!("c{i}")).collect()
    };
    let mut columns: Vec<Vec<f32>> = vec![Vec::new(); ncols];
    if !is_header {
        for (i, c) in first_cells.iter().enumerate() {
            columns[i].push(c.parse::<f32>().unwrap());
        }
    }
    for (lineno, line) in lines.enumerate() {
        let cells: Vec<&str> = line.split(',').map(str::trim).collect();
        if cells.len() != ncols {
            bail!("line {}: {} cells, expected {ncols}", lineno + 2, cells.len());
        }
        for (i, c) in cells.iter().enumerate() {
            columns[i].push(
                c.parse::<f32>()
                    .with_context(|| format!("line {}: bad number '{c}'", lineno + 2))?,
            );
        }
    }
    Table::new(names, columns)
}

/// Write a table as CSV with a header row.
pub fn write_csv(path: impl AsRef<Path>, table: &Table) -> Result<()> {
    let mut f = fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    writeln!(f, "{}", table.names.join(","))?;
    for row in 0..table.len() {
        let cells: Vec<String> = table.columns.iter().map(|c| c[row].to_string()).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_with_header() {
        let t = parse_csv("x,y\n1,2\n3.5,4\n").unwrap();
        assert_eq!(t.names, vec!["x", "y"]);
        assert_eq!(t.column("x").unwrap(), &[1.0, 3.5]);
        assert_eq!(t.column("y").unwrap(), &[2.0, 4.0]);
        assert!(t.column("z").is_none());
    }

    #[test]
    fn parse_headerless_and_comments() {
        let t = parse_csv("# generated\n1,2\n3,4\n").unwrap();
        assert_eq!(t.names, vec!["c0", "c1"]);
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(parse_csv("a,b\n1\n").is_err());
        assert!(parse_csv("a,b\n1,x\n").is_err());
    }

    #[test]
    fn roundtrip_via_tempfile() {
        let t = Table::new(
            vec!["x".into(), "y".into()],
            vec![vec![0.25, -1.5], vec![3.0, 4.0]],
        )
        .unwrap();
        let path = std::env::temp_dir().join("parccm_io_test.csv");
        write_csv(&path, &t).unwrap();
        let got = read_csv(&path).unwrap();
        assert_eq!(got, t);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn empty_input() {
        let t = parse_csv("").unwrap();
        assert!(t.is_empty());
    }
}
