//! `parccm` — the coordinator binary.
//!
//! Subcommands are rows of one [`SUBCOMMANDS`] table (run `parccm help`
//! for the list, `parccm <sub> --help` for a subcommand's own usage).
//! Batch analysis: `cases`, `fig4`, `elasticity`, `quickstart`, `sweep`,
//! `validate`, `significance`, `select`, `forecast`, `lag`, `events`.
//! Serve mode (one warm worker pool, many concurrent jobs — see
//! [`parccm::ccm::serve`]): `serve` runs the daemon; `submit`, `status`,
//! `fetch`, and `cancel` are its job clients. `worker` is the hidden
//! cluster child entry point.

use std::process::ExitCode;
use std::sync::Arc;

use parccm::baseline::{redm_ccm, RedmConfig};
use parccm::bench::report::{Row, TablePrinter};
use parccm::ccm::backend::ComputeBackend;
use parccm::ccm::convergence::assess;
use parccm::ccm::chaos::chaos_from_env;
use parccm::ccm::cluster::{ClusterBackend, ClusterOptions, OnExhausted};
use parccm::ccm::driver::{skills_to_json, Case, JobSpec, ReduceMode, RunSpec, TablePolicy};
use parccm::ccm::lifecycle::{parse_workers_at, workers_at_from_env};
use parccm::ccm::params::{CcmParams, Scenario};
use parccm::ccm::pipeline::PartialSpec;
use parccm::ccm::serve::{JobClient, ServeDaemon, ServeOptions, DEFAULT_MAX_CONCURRENT_JOBS};
use parccm::ccm::transport::{resolve_auth_token, TransportKind};
use parccm::ccm::result::summarize;
use parccm::ccm::surrogate::{significance_test, SurrogateKind};
use parccm::engine::Deploy;
use parccm::native::NativeBackend;
use parccm::runtime::{artifacts_available, XlaBackend, DEFAULT_ARTIFACTS_DIR};
use parccm::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
use parccm::timeseries::io::read_csv;
use parccm::util::cli::Args;
use parccm::util::json::Json;

/// One row of the dispatch table: name, one-line description for the
/// global help, full usage text for `parccm <name> --help`, and the
/// handler. Hidden rows dispatch but stay out of the global help.
struct Subcommand {
    name: &'static str,
    about: &'static str,
    usage: &'static str,
    hidden: bool,
    run: fn(&Args) -> ExitCode,
}

/// The dispatch table. `main` resolves the subcommand here; the
/// help-coverage test pins every row to a non-empty about line and a
/// usage block that leads with its own invocation.
const SUBCOMMANDS: &[Subcommand] = &[
    Subcommand {
        name: "cases",
        about: "print Table 1 (implementation levels A1-A5)",
        usage: "USAGE: parccm cases",
        hidden: false,
        run: cmd_cases,
    },
    Subcommand {
        name: "fig4",
        about: "Fig. 4: A1-A5 x (Local|Cluster) on the baseline scenario",
        usage: "USAGE: parccm fig4 [--full] [--case A1..A5] [--backend B] \
                [--table full|trunc] [--shards N] [--reduce driver|worker] \
                [--partial EPS,CONF] [--dump-skills FILE] [--seed N] \
                [--workers N --cores N]\n\
                \n\
                Runs the paper's five implementation levels and reports the\n\
                DES makespan for Local and Yarn topologies. --dump-skills\n\
                writes the canonical skills JSON plus FILE.meta.json (v3\n\
                sidecar: schema_version + a counters sub-object; no flat\n\
                counter keys). --partial stops dispatching a cell's\n\
                remaining subsamples once its mean-rho CI at confidence\n\
                CONF is within EPS (unset = exact full-budget run).",
        hidden: false,
        run: cmd_fig4,
    },
    Subcommand {
        name: "elasticity",
        about: "Table 2 / Fig. 5: runtime elasticity in L, E, tau",
        usage: "USAGE: parccm elasticity [--full] [--backend B] [--seed N]",
        hidden: false,
        run: cmd_elasticity,
    },
    Subcommand {
        name: "quickstart",
        about: "end-to-end convergence demo on coupled logistic maps",
        usage: "USAGE: parccm quickstart [--n N] [--r R] [--l L1,L2,...] [--backend B]",
        hidden: false,
        run: cmd_quickstart,
    },
    Subcommand {
        name: "sweep",
        about: "CCM over a CSV: --input f.csv --effect col --cause col",
        usage: "USAGE: parccm sweep --input series.csv [--effect col] [--cause col] \
                [--r R] [--l ...] [--e ...] [--tau ...] [--backend B]",
        hidden: false,
        run: cmd_sweep,
    },
    Subcommand {
        name: "validate",
        about: "cross-check XLA backend vs native backend",
        usage: "USAGE: parccm validate [--artifacts DIR] [--seed N]",
        hidden: false,
        run: cmd_validate,
    },
    Subcommand {
        name: "significance",
        about: "surrogate significance test demo",
        usage: "USAGE: parccm significance [--n N] [--l L] [--r R] [--surrogates K] [--seed N]",
        hidden: false,
        run: cmd_significance,
    },
    Subcommand {
        name: "select",
        about: "choose (E, tau): Cao / AMI / forecast-skill (--input csv --col name)",
        usage: "USAGE: parccm select [--input series.csv --col name] [--max-e E] \
                [--max-lag L] [--bins B] [--cao-tol T]",
        hidden: false,
        run: cmd_select,
    },
    Subcommand {
        name: "forecast",
        about: "simplex & S-map forecast skill (--input csv --col name)",
        usage: "USAGE: parccm forecast [--input series.csv --col name] [--e E] \
                [--tau T] [--theta X]",
        hidden: false,
        run: cmd_forecast,
    },
    Subcommand {
        name: "lag",
        about: "cross-map lag profile (delayed-causality analysis)",
        usage: "USAGE: parccm lag [--n N] [--e E] [--tau T] [--l L] [--r R] \
                [--max-lag K] [--backend B]",
        hidden: false,
        run: cmd_lag,
    },
    Subcommand {
        name: "events",
        about: "run a demo job set, dump the engine event log + DES reports",
        usage: "USAGE: parccm events [--out FILE] [--replicas R] [--sim-failures N] \
                [--sim-rejoins N] [--sim-speculative N] [--sim-partial-saved N] \
                [--sim-concurrent-jobs N] [--backend B]\n\
                \n\
                --sim-concurrent-jobs N prices the measured log as N tenant\n\
                jobs sharing the warm pool (broadcast bytes do not grow; the\n\
                makespan reflects slot contention). --sim-partial-saved N\n\
                prices N tasks skipped by --partial early termination at\n\
                the mean measured task duration.",
        hidden: false,
        run: cmd_events,
    },
    Subcommand {
        name: "serve",
        about: "run the multi-tenant job daemon over one warm worker pool",
        usage: "USAGE: parccm serve [--serve-at HOST:PORT] [--max-concurrent-jobs N] \
                [--auth-token T] [--backend process ...cluster flags]\n\
                \n\
                Owns one warm pool for its whole life and admits many\n\
                concurrent jobs over the v7 wire (submit/status/fetch/\n\
                cancel). Announces `PARCCM_SERVE_LISTENING host:port` on\n\
                stdout; runs until a client sends shutdown, then drains.\n\
                --serve-at defaults to 127.0.0.1:0 (ephemeral). At most\n\
                --max-concurrent-jobs run at once (default 4); excess\n\
                submissions queue FIFO. With --backend process (or\n\
                --workers-at) jobs share the cluster pool with per-job\n\
                counters and fair round-robin dispatch; other backends\n\
                serve without per-job attribution.",
        hidden: false,
        run: cmd_serve,
    },
    Subcommand {
        name: "submit",
        about: "submit a job to a serve daemon; prints the job id",
        usage: "USAGE: parccm submit --at HOST:PORT [--case A1..A5] [--full] \
                [--table full|trunc] [--shards N] [--reduce driver|worker] \
                [--partial EPS,CONF] [--seed N] [--auth-token T]\n\
                \n\
                Builds the same spec `parccm fig4 --case ...` would run and\n\
                submits it; prints the assigned job id on stdout. The\n\
                daemon's result is byte-identical to the batch\n\
                --dump-skills output for the same flags.",
        hidden: false,
        run: cmd_submit,
    },
    Subcommand {
        name: "status",
        about: "print a submitted job's state and per-job counters",
        usage: "USAGE: parccm status --at HOST:PORT --job N [--auth-token T]\n\
                \n\
                Prints the daemon's status reply as JSON: state (queued|\n\
                running|done|failed|cancelled), the job's live counter\n\
                slice (including partial_stops/partial_saved_tasks), the\n\
                cancelled_running marker, and the failure message when\n\
                failed.",
        hidden: false,
        run: cmd_status,
    },
    Subcommand {
        name: "fetch",
        about: "fetch a done job's canonical skills dump",
        usage: "USAGE: parccm fetch --at HOST:PORT --job N [--out FILE] [--wait] \
                [--auth-token T]\n\
                \n\
                Writes the canonical skills JSON to --out (exact bytes, no\n\
                trailing newline — byte-comparable against a batch\n\
                --dump-skills file) or stdout. --wait polls status until\n\
                the job leaves the queue/running states first.",
        hidden: false,
        run: cmd_fetch,
    },
    Subcommand {
        name: "cancel",
        about: "cancel a queued or running job on a serve daemon (or --shutdown the daemon)",
        usage: "USAGE: parccm cancel --at HOST:PORT (--job N | --shutdown) [--auth-token T]\n\
                \n\
                A queued job cancels immediately (reply state `cancelled`).\n\
                A running job cancels best-effort (reply `cancelling`): the\n\
                driver stops at its next partial-evaluation checkpoint and\n\
                the job settles cancelled with cancelled_running:true in\n\
                status — unless the run finishes first, which settles done.\n\
                Finished jobs are a named error. --shutdown instead asks\n\
                the daemon to stop accepting jobs and drain.",
        hidden: false,
        run: cmd_cancel,
    },
    Subcommand {
        name: "worker",
        about: "cluster child entry point (JSON wire on stdio, or --listen/--connect TCP)",
        usage: "USAGE: parccm worker [--listen HOST:PORT | --connect HOST:PORT] \
                [--auth-token T]",
        hidden: true,
        run: parccm::ccm::cluster::worker_main,
    },
];

fn main() -> ExitCode {
    let args = Args::from_env();
    let Some(name) = args.subcommand.as_deref() else {
        print_help();
        return ExitCode::SUCCESS;
    };
    if name == "help" {
        print_help();
        return ExitCode::SUCCESS;
    }
    match SUBCOMMANDS.iter().find(|s| s.name == name) {
        Some(sub) => {
            if args.flag("help") {
                println!("{}", sub.usage);
                return ExitCode::SUCCESS;
            }
            (sub.run)(&args)
        }
        None => {
            eprintln!("unknown subcommand '{name}'\n");
            print_help();
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "parccm — Parallelizing Convergent Cross Mapping (paper reproduction)\n\
         \n\
         USAGE: parccm <subcommand> [options]   (parccm <subcommand> --help for details)\n\
         \n\
         SUBCOMMANDS"
    );
    for sub in SUBCOMMANDS {
        if !sub.hidden {
            println!("  {:<14} {}", sub.name, sub.about);
        }
    }
    println!(
        "\n\
         COMMON OPTIONS\n\
           --full               paper-scale scenario (default: scaled for 1 core)\n\
           --backend native|xla|process\n\
                                (default: xla when artifacts/ exists, else native;\n\
                                process = the cluster runtime: worker processes)\n\
           --proc-workers N     worker processes for --backend process (default 2)\n\
           --transport pipe|tcp transport to the workers (default pipe; tcp =\n\
                                loopback sockets, same wire protocol + results)\n\
           --workers-at H:P,... connect to pre-started `parccm worker --listen`\n\
                                processes instead of forking (implies tcp; pool\n\
                                width = address count; env: PARCCM_WORKERS)\n\
           --auth-token T       shared handshake secret for driver + workers\n\
                                (env: PARCCM_AUTH_TOKEN)\n\
           --keepalive-secs S   ping idle workers every S seconds, discard the\n\
                                silent ones (default: 5 for --workers-at pools,\n\
                                off otherwise; 0 disables)\n\
           --rejoin-backoff-secs S\n\
                                redial dead --workers-at addresses on an\n\
                                exponential backoff starting at S seconds, so a\n\
                                restarted `parccm worker --listen` on the same\n\
                                port rejoins the pool (default 0 = off; auth\n\
                                mismatch on rejoin retires the address)\n\
           --replicas R         keep each broadcast resident on R workers so a\n\
                                dead worker's tasks requeue with zero re-ship\n\
                                (default 1; clamped to the pool width)\n\
           --task-deadline-secs S\n\
                                kill + requeue any cluster task still running\n\
                                after S seconds (default: off)\n\
           --speculate-factor X launch a speculative duplicate of any task\n\
                                running longer than X times the running median\n\
                                for its kind; first result wins (default: off)\n\
           --on-exhausted abort|fallback\n\
                                when a task fails all its attempts: abort the\n\
                                run (default), or fall back to the in-process\n\
                                native backend for that task (bit-identical\n\
                                results, counted as exhausted_fallbacks)\n\
           PARCCM_CHAOS=seed:spec\n\
                                deterministic fault injection on every cluster\n\
                                connection (spec keys: delay=N, delay_ms=M,\n\
                                drop=N, trunc=N, corrupt=N, corrupt_send=N,\n\
                                corrupt_recv=N, corrupt_once=N); corrupt frames\n\
                                are caught by the v4 wire checksum\n\
           --artifacts DIR      artifact directory (default: artifacts)\n\
           --table full|trunc   distance-table layout for A4/A5 (default: trunc,\n\
                                the O(n*P) truncated broadcast; bit-identical skills)\n\
           --shards N           split the distance table into N row-range shards,\n\
                                one broadcast + transform job per shard (default 1)\n\
           --reduce driver|worker\n\
                                where the Pearson reduction runs for sharded table\n\
                                cases: driver (default) ships raw prediction rows\n\
                                back and concatenates; worker reduces each shard\n\
                                to six partial sums on the worker (v5 wire ops\n\
                                agg_chunk/merge_sums) — same skills to within\n\
                                1 ULP, result ingress O(shards) instead of O(rows)\n\
           --partial EPS,CONF   early-terminating partial CCM: stop dispatching a\n\
                                grid cell's remaining subsamples once its mean-rho\n\
                                confidence interval at level CONF has radius <= EPS,\n\
                                and prune statistically dead (E,tau) slices (unset:\n\
                                exact full-budget run, bit-identical skills)\n\
           --case A1..A5        fig4: run a single implementation level\n\
           --dump-skills FILE   fig4: write skills as canonical JSON (two runs are\n\
                                bit-identical iff the files are byte-identical);\n\
                                also writes FILE.meta.json with the backend's run\n\
                                counters (rejoins, repair ships, ...)\n\
           --seed N             master seed\n\
           --workers N --cores N   cluster topology for the DES (default 5x4)\n"
    );
}

/// Parse the cluster-pool flags shared by every command that can own a
/// worker pool (`fig4 --backend process`, `serve`, ...): transport,
/// remote addresses, auth, keepalive/rejoin, straggler defense, chaos.
/// Malformed values that would silently change semantics are fatal.
fn cluster_options_from(args: &Args) -> ClusterOptions {
    let workers = args.get_usize("proc-workers", 2);
    let replicas = args.get_usize("replicas", 1);
    let transport = match args.get("transport") {
        None => TransportKind::Pipe,
        Some(t) => match TransportKind::parse(t) {
            Some(k) => k,
            None => {
                eprintln!("[parccm] unknown --transport '{t}', using pipe");
                TransportKind::Pipe
            }
        },
    };
    // pre-started remote workers: --workers-at, else PARCCM_WORKERS
    let workers_at = match args.get("workers-at") {
        Some(list) => {
            let addrs = parse_workers_at(list);
            if addrs.is_empty() {
                // asking for remote mode and getting local numbers
                // would hide a dead cluster — refuse loudly
                eprintln!(
                    "[parccm] FATAL: --workers-at '{list}' names no host:port \
                     (expected a comma-separated list like hostA:7001,hostB:7001)"
                );
                std::process::exit(2);
            }
            addrs
        }
        None => workers_at_from_env().unwrap_or_default(),
    };
    let explicit_pipe = args.get("transport").is_some() && transport == TransportKind::Pipe;
    if !workers_at.is_empty() && explicit_pipe {
        eprintln!("[parccm] --workers-at implies --transport tcp; ignoring 'pipe'");
    }
    let auth_token = resolve_auth_token(args.get("auth-token"));
    // --keepalive-secs S (<= 0 disables); unset = automatic (on
    // for remote pools, off for forked ones)
    let keepalive = args.get("keepalive-secs").map(|_| {
        let secs = args.get_f64("keepalive-secs", 0.0).max(0.0);
        std::time::Duration::from_secs_f64(secs)
    });
    if keepalive.is_some_and(|d| !d.is_zero())
        && workers_at.is_empty()
        && transport == TransportKind::Pipe
    {
        eprintln!(
            "[parccm] --keepalive-secs has no effect on the pipe transport \
             (pipes cannot enforce read deadlines); use --transport tcp"
        );
    }
    // --rejoin-backoff-secs S (0 = off): redial dead remote
    // addresses so restarted listeners rejoin the pool
    let rejoin_backoff = args.get("rejoin-backoff-secs").map(|_| {
        let secs = args.get_f64("rejoin-backoff-secs", 0.0).max(0.0);
        std::time::Duration::from_secs_f64(secs)
    });
    if rejoin_backoff.is_some_and(|d| !d.is_zero()) && workers_at.is_empty() {
        eprintln!(
            "[parccm] --rejoin-backoff-secs only applies to --workers-at pools \
             (forked workers are respawned in place); ignoring it"
        );
    }
    // straggler defense: a hard per-task deadline and/or speculative
    // duplicates keyed to the running median duration per task kind
    let task_deadline = args.get("task-deadline-secs").and_then(|_| {
        let secs = args.get_f64("task-deadline-secs", 0.0);
        (secs > 0.0).then(|| std::time::Duration::from_secs_f64(secs))
    });
    let speculate_factor = args.get("speculate-factor").and_then(|_| {
        let x = args.get_f64("speculate-factor", 0.0);
        (x > 0.0).then_some(x)
    });
    let on_exhausted = match args.get("on-exhausted") {
        None => OnExhausted::Abort,
        Some(p) => match OnExhausted::parse(p) {
            Some(o) => o,
            None => {
                eprintln!(
                    "[parccm] FATAL: unknown --on-exhausted '{p}' \
                     (expected abort|fallback)"
                );
                std::process::exit(2);
            }
        },
    };
    // a malformed chaos spec must never silently run chaos-free:
    // the whole point of PARCCM_CHAOS is a reproducible fault plan
    let chaos = match chaos_from_env() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("[parccm] FATAL: {e}");
            std::process::exit(2);
        }
    };
    if let Some((seed, _)) = &chaos {
        eprintln!(
            "[parccm] chaos injection armed on driver-side connections \
             (PARCCM_CHAOS, seed {seed})"
        );
    }
    ClusterOptions {
        transport,
        workers,
        replicas,
        workers_at,
        auth_token,
        keepalive,
        rejoin_backoff,
        task_deadline,
        speculate_factor,
        on_exhausted,
        chaos,
        ..ClusterOptions::default()
    }
}

/// Pick the compute backend: explicit `--backend`, else XLA when artifacts
/// are present, else native.
fn make_backend(args: &Args) -> Arc<dyn ComputeBackend> {
    let dir = args.get("artifacts").unwrap_or(DEFAULT_ARTIFACTS_DIR).to_string();
    let mut choice = args.get("backend").unwrap_or(if artifacts_available(&dir) {
        "xla"
    } else {
        "native"
    });
    // an explicit --workers-at must never be silently ignored: it implies
    // the cluster backend, and contradicting an explicit --backend is an
    // error, not a local run with correct-looking numbers
    if args.get("workers-at").is_some() && choice != "process" {
        if args.get("backend").is_some() {
            eprintln!(
                "[parccm] FATAL: --workers-at requires --backend process \
                 (got --backend {choice})"
            );
            std::process::exit(2);
        }
        eprintln!("[parccm] --workers-at implies --backend process");
        choice = "process";
    }
    match choice {
        "xla" => {
            let pool = args.get_usize("xla-pool", 1);
            match XlaBackend::from_dir(&dir, pool) {
                Ok(b) => {
                    eprintln!("[parccm] backend: xla (artifacts: {dir}, pool: {pool})");
                    Arc::new(b)
                }
                Err(e) => {
                    eprintln!("[parccm] xla backend unavailable ({e:#}); using native");
                    Arc::new(NativeBackend)
                }
            }
        }
        "process" => {
            let opts = cluster_options_from(args);
            let remote = !opts.workers_at.is_empty();
            let spawned = std::env::current_exe()
                .and_then(|exe| ClusterBackend::with_options(exe, opts));
            match spawned {
                Ok(b) => {
                    eprintln!(
                        "[parccm] backend: cluster ({} {} workers, transport {}, replicas {})",
                        b.num_workers(),
                        if remote { "remote" } else { "forked" },
                        b.transport_kind().name(),
                        b.replicas()
                    );
                    Arc::new(b)
                }
                Err(e) if remote => {
                    // a silent native fallback would still produce correct
                    // numbers, hiding a dead cluster — fail loudly instead
                    eprintln!(
                        "[parccm] FATAL: cannot connect the remote worker pool ({e}); \
                         check --workers-at / PARCCM_WORKERS and that every listener \
                         uses the same auth token"
                    );
                    std::process::exit(2);
                }
                Err(e) => {
                    eprintln!("[parccm] cluster backend unavailable ({e}); using native");
                    Arc::new(NativeBackend)
                }
            }
        }
        "native" => {
            eprintln!("[parccm] backend: native");
            Arc::new(NativeBackend)
        }
        other => {
            eprintln!("[parccm] unknown backend '{other}', using native");
            Arc::new(NativeBackend)
        }
    }
}

fn scenario_from(args: &Args) -> Scenario {
    let mut s = if args.flag("full") {
        Scenario::paper_baseline()
    } else {
        Scenario::scaled_baseline()
    };
    s.seed = args.get_u64("seed", s.seed);
    s.r = args.get_usize("r", s.r);
    if args.get("l").is_some() {
        s.ls = args.get_usize_list("l", &s.ls);
    }
    if args.get("e").is_some() {
        s.es = args.get_usize_list("e", &s.es);
    }
    if args.get("tau").is_some() {
        s.taus = args.get_usize_list("tau", &s.taus);
    }
    s.partitions = args.get_usize("partitions", s.partitions);
    s
}

fn cluster_from(args: &Args) -> Deploy {
    Deploy::Cluster {
        workers: args.get_usize("workers", 5),
        cores_per_worker: args.get_usize("cores", 4),
    }
}

/// Distance-table layout for the table cases: `--table full` keeps the
/// paper's O(n^2) broadcast; the default truncates to O(n*P).
fn table_policy_from(args: &Args) -> TablePolicy {
    match args.get("table") {
        Some("full") => TablePolicy::Full,
        _ => TablePolicy::TruncatedAuto,
    }
}

/// `--partial eps,conf`: early-terminating partial evaluation. Unset is
/// the exact full-budget run (bit-identical skills); a malformed value is
/// fatal — a typo must not silently run the full grid or a wrong bound.
fn partial_from(args: &Args) -> Option<PartialSpec> {
    let raw = args.get("partial")?;
    match PartialSpec::parse(raw) {
        Some(spec) => Some(spec),
        None => {
            eprintln!(
                "[parccm] FATAL: bad --partial '{raw}' (want eps,conf with eps > 0 \
                 and conf in (0,1), e.g. 0.05,0.95)"
            );
            std::process::exit(2);
        }
    }
}

/// Pearson reduction placement for sharded table cases: `--reduce worker`
/// keeps raw predictions on the workers and ships six partial sums per
/// (skill, shard) instead; the default ships the rows.
fn reduce_from(args: &Args) -> ReduceMode {
    match args.get("reduce") {
        None => ReduceMode::Driver,
        Some(m) => match ReduceMode::parse(m) {
            Some(r) => r,
            None => {
                eprintln!("[parccm] FATAL: unknown --reduce '{m}' (expected driver|worker)");
                std::process::exit(2);
            }
        },
    }
}

/// A [`RunSpec`] with the table layout, shard count, and reduce placement
/// picked from the command's own `--table` / `--shards` / `--reduce`
/// arguments.
#[allow(clippy::too_many_arguments)]
fn run_case(
    args: &Args,
    case: Case,
    scenario: &Scenario,
    effect: &[f32],
    cause: &[f32],
    deploy: Deploy,
    backend: Arc<dyn ComputeBackend>,
) -> parccm::ccm::driver::CaseReport {
    RunSpec::new(case, scenario, effect, cause)
        .deploy(deploy)
        .policy(table_policy_from(args))
        .shards(args.get_usize("shards", 1))
        .reduce(reduce_from(args))
        .partial(partial_from(args))
        .run(backend)
}

fn cmd_cases(_args: &Args) -> ExitCode {
    println!("Table 1. Implementation Levels");
    for case in Case::ALL {
        println!("  Case {}  {}", case.name(), case.description());
    }
    ExitCode::SUCCESS
}

fn cmd_fig4(args: &Args) -> ExitCode {
    let scenario = scenario_from(args);
    let backend = make_backend(args);
    let cluster = cluster_from(args);
    let local = Deploy::Local { cores: args.get_usize("local-cores", 4) };
    // --case A4 restricts the sweep (the cluster-remote CI job runs one
    // case against two backends and diffs the --dump-skills output)
    let cases: Vec<Case> = match args.get("case") {
        None => Case::ALL.to_vec(),
        Some(name) => match Case::parse(name) {
            Some(c) => vec![c],
            None => {
                eprintln!("unknown --case '{name}' (expected one of A1..A5)");
                return ExitCode::FAILURE;
            }
        },
    };
    println!(
        "Fig. 4 — comparison of parallel levels (series={}, r={}, L={:?}, E={:?}, tau={:?})",
        scenario.series_len, scenario.r, scenario.ls, scenario.es, scenario.taus
    );
    let mut table = TablePrinter::new("Fig 4: average computation time (s)");
    let (x, y) = coupled_logistic(scenario.series_len, CoupledLogisticParams::default());
    let mut all_skills = Vec::new();
    for case in cases {
        // one real execution per case; Local and Yarn are DES replays of
        // the same event log (numerics are deploy-independent)
        let (skills, reports) = RunSpec::new(case, &scenario, &y, &x)
            .policy(table_policy_from(args))
            .shards(args.get_usize("shards", 1))
            .reduce(reduce_from(args))
            .partial(partial_from(args))
            .run_multi(&[local.clone(), cluster.clone()], Arc::clone(&backend));
        all_skills.extend(skills);
        table.push(
            Row::new(format!("{} {}", case.name(), case.description()))
                .cell("local_sim_s", reports[0].sim_makespan_s)
                .cell("yarn_sim_s", reports[1].sim_makespan_s)
                .cell("measured_s", reports[1].measured_wall_s)
                .cell("task_s", reports[1].total_task_s)
                .cell("util", reports[1].sim_utilization),
        );
    }
    table.print();
    let _ = table.save("results/fig4.json");
    if let Some(path) = args.get("dump-skills") {
        // canonical, full-precision dump: byte-identical across backends
        // iff the skills are bit-identical
        if let Some(parent) = std::path::Path::new(path).parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        if let Err(e) = std::fs::write(path, skills_to_json(&all_skills).to_string()) {
            eprintln!("cannot write --dump-skills {path}: {e}");
            return ExitCode::FAILURE;
        }
        println!("(skills dumped to {path})");
        // run metadata rides in a sidecar, never in the skills file: the
        // skills dump must stay byte-comparable across backends while the
        // counters (rejoins, repair ships, ...) legitimately differ — the
        // cluster-remote CI job asserts the rejoin counters from here
        let pairs = backend.run_counters().to_pairs();
        // sidecar schema v3: every counter lives in the .counters
        // sub-object and nowhere else (v2's legacy flat mirror of the
        // counter keys at top level is gone)
        let meta = Json::obj(vec![
            ("backend", Json::Str(backend.name().to_string())),
            (
                "counters",
                Json::obj(pairs.iter().map(|&(k, v)| (k, Json::Num(v as f64))).collect()),
            ),
            ("schema_version", Json::Num(3.0)),
        ]);
        let meta_path = format!("{path}.meta.json");
        if let Err(e) = std::fs::write(&meta_path, meta.to_string()) {
            eprintln!("cannot write run metadata {meta_path}: {e}");
            return ExitCode::FAILURE;
        }
        println!("(run metadata dumped to {meta_path})");
    }
    println!("\n(saved results/fig4.json; `cargo bench --bench fig4_cases` adds repeats + rEDM)");
    ExitCode::SUCCESS
}

fn cmd_elasticity(args: &Args) -> ExitCode {
    let base = scenario_from(args);
    let backend = make_backend(args);
    let cluster = cluster_from(args);
    let (x, y) = coupled_logistic(base.series_len, CoupledLogisticParams::default());
    // Table 2: vary one parameter, others at the smallest baseline value.
    let (l0, e0, t0) = (base.ls[0], 1, 1);
    let mut table = TablePrinter::new("Table 2 / Fig 5: elasticity (seconds; ratio vs first)");
    let mut run_cell = |label: String, e: usize, tau: usize, l: usize| -> (f64, f64) {
        let mut s = base.clone();
        s.es = vec![e];
        s.taus = vec![tau];
        s.ls = vec![l];
        let single =
            run_case(args, Case::A1, &s, &y, &x, Deploy::SingleThread, Arc::clone(&backend));
        let parallel = run_case(args, Case::A5, &s, &y, &x, cluster.clone(), Arc::clone(&backend));
        let st = single.report.measured_wall_s;
        let pt = parallel.report.sim_makespan_s;
        table.push(
            Row::new(label)
                .cell("single_s", st)
                .cell("parallel_sim_s", pt)
                .cell("speedup", st / pt.max(1e-12)),
        );
        (st, pt)
    };
    let mut firsts: Vec<(String, f64, f64)> = Vec::new();
    for &l in &base.ls {
        let (s, p) = run_cell(format!("L={l} (E={e0},tau={t0})"), e0, t0, l);
        if l == base.ls[0] {
            firsts.push(("L".into(), s, p));
        }
    }
    for &e in &base.es {
        let (s, p) = run_cell(format!("E={e} (L={l0},tau={t0})"), e, t0, l0);
        if e == base.es[0] {
            firsts.push(("E".into(), s, p));
        }
    }
    for &tau in &base.taus {
        let (s, p) = run_cell(format!("tau={tau} (L={l0},E={e0})"), e0, tau, l0);
        if tau == base.taus[0] {
            firsts.push(("tau".into(), s, p));
        }
    }
    table.print();
    let _ = table.save("results/elasticity.json");
    println!("\n(paper: doubling L -> 4.06x single-threaded vs 1.11x parallel)");
    ExitCode::SUCCESS
}

fn cmd_quickstart(args: &Args) -> ExitCode {
    let backend = make_backend(args);
    let n = args.get_usize("n", 1000);
    let (x, y) = coupled_logistic(n, CoupledLogisticParams::default());
    let mut scenario = Scenario::smoke();
    scenario.series_len = n;
    scenario.r = args.get_usize("r", 20);
    scenario.ls = args.get_usize_list("l", &[100, 200, 400, 800]);
    scenario.es = vec![2];
    scenario.taus = vec![1];
    println!("CCM quickstart: does X drive Y? (coupled logistic, beta_yx=0.1 >> beta_xy=0.02)");
    let rep = run_case(args, Case::A5, &scenario, &y, &x, Deploy::paper_cluster(), backend);
    let summaries = summarize(&rep.skills);
    println!("\n   L     mean rho    std");
    for s in &summaries {
        println!("{:>5}     {:>7.4}  {:>6.4}", s.params.l, s.mean_rho, s.std_rho);
    }
    let verdict = assess(&summaries, 0.1, 0.02);
    println!(
        "\nconvergence: delta={:.4}, increasing={}, causal={}",
        verdict.delta, verdict.increasing, verdict.causal
    );
    println!(
        "engine: measured {:.3}s, simulated cluster makespan {:.3}s (util {:.0}%)",
        rep.report.measured_wall_s,
        rep.report.sim_makespan_s,
        rep.report.sim_utilization * 100.0
    );
    ExitCode::SUCCESS
}

fn cmd_sweep(args: &Args) -> ExitCode {
    let Some(input) = args.get("input") else {
        eprintln!("sweep requires --input series.csv (plus --effect/--cause column names)");
        return ExitCode::FAILURE;
    };
    let table = match read_csv(input) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("failed to read {input}: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    let effect_name = args.get("effect").unwrap_or("y");
    let cause_name = args.get("cause").unwrap_or("x");
    let (Some(effect), Some(cause)) = (table.column(effect_name), table.column(cause_name))
    else {
        eprintln!(
            "columns '{effect_name}'/'{cause_name}' not found; available: {:?}",
            table.names
        );
        return ExitCode::FAILURE;
    };
    let effect = effect.to_vec();
    let cause = cause.to_vec();
    let backend = make_backend(args);
    let n = effect.len();
    let mut scenario = Scenario::scaled_baseline();
    scenario.series_len = n;
    scenario.r = args.get_usize("r", 50);
    scenario.ls = args.get_usize_list("l", &[n / 8, n / 4, n / 2]);
    scenario.es = args.get_usize_list("e", &[2, 3]);
    scenario.taus = args.get_usize_list("tau", &[1]);
    scenario.seed = args.get_u64("seed", scenario.seed);
    println!("sweep over {input}: {n} points, testing {cause_name} -> {effect_name}");
    let rep = run_case(args, Case::A5, &scenario, &effect, &cause, cluster_from(args), backend);
    let summaries = summarize(&rep.skills);
    println!("\n  E  tau     L    mean rho     std");
    for s in &summaries {
        println!(
            "{:>3} {:>4} {:>5}     {:>7.4} {:>7.4}",
            s.params.e, s.params.tau, s.params.l, s.mean_rho, s.std_rho
        );
    }
    ExitCode::SUCCESS
}

fn cmd_validate(args: &Args) -> ExitCode {
    let dir = args.get("artifacts").unwrap_or(DEFAULT_ARTIFACTS_DIR);
    if !artifacts_available(dir) {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return ExitCode::FAILURE;
    }
    let xla = match XlaBackend::from_dir(dir, 1) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("failed to start XLA backend: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    let native = NativeBackend;
    let (x, y) = coupled_logistic(600, CoupledLogisticParams::default());
    let mut worst = 0.0f32;
    let mut checked = 0usize;
    for (e, tau, l) in [(1usize, 1usize, 50usize), (2, 1, 150), (3, 2, 200), (4, 4, 400)] {
        let problem = parccm::ccm::pipeline::CcmProblem::new(&y, &x, e, tau, 0.0);
        let samples = parccm::ccm::subsample::draw_samples(
            &parccm::util::rng::Rng::new(args.get_u64("seed", 99)),
            CcmParams::new(e, tau, l),
            problem.emb.n,
            3,
        );
        for s in &samples {
            let input = problem.input_for(s);
            let a = xla.cross_map(&input);
            let b = native.cross_map(&input);
            worst = worst.max((a.rho - b.rho).abs());
            checked += 1;
        }
    }
    println!("validate: {checked} cross-maps, max |rho_xla - rho_native| = {worst:.2e}");
    if worst < 1e-4 {
        println!("OK — backends agree");
        ExitCode::SUCCESS
    } else {
        println!("FAIL — divergence above 1e-4");
        ExitCode::FAILURE
    }
}

fn cmd_events(args: &Args) -> ExitCode {
    // run a small A5 workload and dump the Spark-style event log + reports
    // for several topologies (what a Spark History Server would show).
    let backend = make_backend(args);
    let scenario = Scenario::smoke();
    let (x, y) = coupled_logistic(scenario.series_len, CoupledLogisticParams::default());
    let ctx = parccm::engine::Context::new(
        parccm::engine::EngineConfig::new(cluster_from(args))
            .with_default_parallelism(scenario.partitions)
            .with_broadcast_replicas(args.get_usize("replicas", 1))
            .with_sim_worker_failures(args.get_usize("sim-failures", 0))
            .with_sim_worker_rejoins(args.get_usize("sim-rejoins", 0))
            .with_sim_speculative_tasks(args.get_usize("sim-speculative", 0))
            .with_sim_partial_saved_tasks(args.get_usize("sim-partial-saved", 0))
            .with_sim_concurrent_jobs(args.get_usize("sim-concurrent-jobs", 1)),
    );
    let problem = parccm::ccm::pipeline::CcmProblem::new(&y, &x, 2, 1, 0.0);
    let n = problem.emb.n;
    let size = problem.size_bytes();
    let pb = ctx.broadcast(problem, size);
    let policy = table_policy_from(args);
    let min_l = scenario.ls.iter().copied().min().unwrap_or(1);
    let mode = policy.mode_for(n, min_l);
    let table = parccm::ccm::pipeline::table_pipeline_mode(&ctx, &pb, scenario.partitions, mode);
    let master = parccm::util::rng::Rng::new(scenario.seed);
    let mut futs = Vec::new();
    for &l in &scenario.ls {
        let samples = parccm::ccm::subsample::draw_samples(
            &master,
            CcmParams::new(2, 1, l),
            n,
            scenario.r,
        );
        let rdd = ctx.parallelize_with(samples, scenario.partitions);
        let out = parccm::ccm::pipeline::table_transform_rdd(
            &ctx,
            rdd,
            &pb,
            &table,
            Arc::clone(&backend),
        );
        futs.push(ctx.collect_async(&out));
    }
    for f in futs {
        let _ = f.get();
    }
    let path = args.get("out").unwrap_or("results/events.json");
    if let Some(parent) = std::path::Path::new(path).parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    std::fs::write(path, ctx.events().to_json().to_string()).expect("writing event log");
    println!("event log -> {path}");
    for deploy in [
        Deploy::SingleThread,
        Deploy::paper_local(),
        Deploy::paper_cluster(),
    ] {
        let rep = ctx.report_for(deploy);
        println!(
            "  {:<15} makespan {:.4}s  util {:.0}%  ship {:.4}s  repair {:.4}s  rejoin {:.4}s  spec {:.4}s  saved {:.4}s  jobs x{}",
            rep.topology,
            rep.sim_makespan_s,
            rep.sim_utilization * 100.0,
            rep.sim_broadcast_ship_s,
            rep.sim_repair_ship_s,
            rep.sim_rejoin_ship_s,
            rep.sim_speculative_task_s,
            rep.sim_partial_saved_task_s,
            rep.sim_concurrent_jobs
        );
    }
    ExitCode::SUCCESS
}

/// Load `--col` of `--input`, or default to the coupled-logistic X series.
fn load_series(args: &Args, default_n: usize) -> Vec<f32> {
    match args.get("input") {
        Some(path) => {
            let table = read_csv(path).unwrap_or_else(|e| panic!("reading {path}: {e:#}"));
            let col = args.get("col").unwrap_or("x");
            table
                .column(col)
                .unwrap_or_else(|| panic!("column '{col}' not in {:?}", table.names))
                .to_vec()
        }
        None => coupled_logistic(default_n, CoupledLogisticParams::default()).0,
    }
}

fn cmd_select(args: &Args) -> ExitCode {
    use parccm::ccm::select;
    let series = load_series(args, 1000);
    let max_e = args.get_usize("max-e", 6);
    let max_lag = args.get_usize("max-lag", 30);
    let bins = args.get_usize("bins", 16);
    let tau = select::select_tau_ami(&series, max_lag, bins);
    println!("tau (first AMI minimum over {max_lag} lags): {tau}");
    let ami = select::mutual_information(&series, max_lag.min(10), bins);
    println!("  AMI[1..{}] = {:?}", ami.len(), ami.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>());
    let e_cao = select::select_e_cao(&series, tau, max_e, args.get_f64("cao-tol", 0.12));
    let e1 = select::cao_e1(&series, tau, max_e);
    println!("E (Cao E1 saturation): {e_cao}   E1 = {:?}", e1.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>());
    let (e_fc, skills) = select::select_e_forecast(&series, tau, max_e);
    println!("E (best simplex forecast skill): {e_fc}   rho(E) = {:?}", skills.iter().map(|v| (v * 1000.0).round() / 1000.0).collect::<Vec<_>>());
    ExitCode::SUCCESS
}

fn cmd_forecast(args: &Args) -> ExitCode {
    use parccm::ccm::forecast::{simplex_forecast, smap_forecast};
    let series = load_series(args, 1000);
    let e = args.get_usize("e", 2);
    let tau = args.get_usize("tau", 1);
    println!("out-of-sample forecast skill (library = first half):");
    println!("  tp   simplex rho      S-map rho (theta=2)");
    for tp in [1usize, 2, 5, 10] {
        let s = simplex_forecast(&series, e, tau, tp);
        let m = smap_forecast(&series, e, tau, tp, args.get_f64("theta", 2.0));
        println!("  {tp:<4} {:>10.4} {:>18.4}", s.rho, m.rho);
    }
    println!("\nnonlinearity test (S-map theta sweep, tp=1):");
    for theta in [0.0, 0.5, 1.0, 2.0, 4.0, 8.0] {
        let r = smap_forecast(&series, e, tau, 1, theta);
        println!("  theta={theta:<4} rho={:.4}", r.rho);
    }
    println!("(skill peaking at theta > 0 indicates state-dependent, nonlinear dynamics)");
    ExitCode::SUCCESS
}

fn cmd_lag(args: &Args) -> ExitCode {
    use parccm::ccm::lagmap::lag_profile;
    let backend = make_backend(args);
    let n = args.get_usize("n", 800);
    let (x, y) = coupled_logistic(n, CoupledLogisticParams::default());
    let params = CcmParams::new(args.get_usize("e", 2), args.get_usize("tau", 1), args.get_usize("l", n / 3));
    let profile = lag_profile(
        &y,
        &x,
        params,
        args.get_usize("r", 5),
        0.0,
        args.get_usize("max-lag", 5),
        args.get_u64("seed", 17),
        backend,
    );
    println!("cross-map skill vs lag (X -> Y on coupled logistic):");
    for (lag, rho) in &profile.skills {
        let bar = "#".repeat((rho.max(0.0) * 40.0) as usize);
        println!("  lag={lag:>3}  rho={rho:+.4}  {bar}");
    }
    println!("peak at lag {} (rho {:.4})", profile.best_lag, profile.best_rho);
    println!("(a causal X -> Y link peaks at lag <= 0: the effect encodes the cause's past)");
    ExitCode::SUCCESS
}

fn cmd_significance(args: &Args) -> ExitCode {
    let backend = make_backend(args);
    let n = args.get_usize("n", 600);
    let (x, y) = coupled_logistic(n, CoupledLogisticParams::default());
    let params = CcmParams::new(2, 1, args.get_usize("l", n / 3));
    let rep = significance_test(
        &y,
        &x,
        params,
        args.get_usize("r", 10),
        0.0,
        SurrogateKind::CircularShift,
        args.get_usize("surrogates", 19),
        args.get_u64("seed", 4242),
        backend,
    );
    println!(
        "observed rho = {:.4}; null mean = {:.4}; p = {:.3}",
        rep.observed_rho,
        rep.null_rhos.iter().sum::<f64>() / rep.null_rhos.len().max(1) as f64,
        rep.p_value
    );
    println!("verdict: X -> Y is {}", if rep.p_value <= 0.05 { "significant" } else { "not significant" });
    // rEDM-style single combo for flavour
    let rows = redm_ccm(
        &y,
        &x,
        &RedmConfig { params, r: 5, theiler: 0.0, seed: 1 },
    );
    println!("(rEDM-baseline check: mean rho {:.4})", rows.iter().map(|r| r.rho as f64).sum::<f64>() / 5.0);
    ExitCode::SUCCESS
}

// ---------------------------------------------------------------------------
// Serve mode: the daemon and its job clients.
// ---------------------------------------------------------------------------

/// Connection every serve client starts from: `--at HOST:PORT` (the
/// address the daemon announced as `PARCCM_SERVE_LISTENING`) plus the
/// usual auth-token resolution.
fn connect_serve_client(args: &Args) -> Result<JobClient, ExitCode> {
    let Some(at) = args.get("at") else {
        eprintln!(
            "this subcommand needs --at HOST:PORT (the daemon prints \
             `PARCCM_SERVE_LISTENING host:port` on startup)"
        );
        return Err(ExitCode::FAILURE);
    };
    let auth = resolve_auth_token(args.get("auth-token"));
    JobClient::connect(at, auth.as_deref()).map_err(|e| {
        eprintln!("cannot connect to serve daemon at {at}: {e}");
        ExitCode::FAILURE
    })
}

/// `--job N`, required: the id `parccm submit` printed.
fn job_arg(args: &Args) -> Result<u64, ExitCode> {
    if args.get("job").is_none() {
        eprintln!("this subcommand needs --job N (the id `parccm submit` printed)");
        return Err(ExitCode::FAILURE);
    }
    Ok(args.get_u64("job", 0))
}

fn cmd_serve(args: &Args) -> ExitCode {
    let max_concurrent = args.get_usize("max-concurrent-jobs", DEFAULT_MAX_CONCURRENT_JOBS);
    let opts = ServeOptions {
        listen: args.get("serve-at").unwrap_or("127.0.0.1:0").to_string(),
        auth_token: resolve_auth_token(args.get("auth-token")),
        max_concurrent_jobs: max_concurrent,
    };
    // The daemon owns ONE pool for its whole life; every job shares it.
    // `--backend process` (or any `--workers-at`) gets the warm cluster
    // pool with per-job counters and fair dispatch; native/xla serve the
    // same protocol on a shared in-process backend.
    let wants_cluster = args.get("backend") == Some("process") || args.get("workers-at").is_some();
    let started = if wants_cluster {
        let cluster_opts = cluster_options_from(args);
        let remote = !cluster_opts.workers_at.is_empty();
        match std::env::current_exe()
            .and_then(|exe| ClusterBackend::with_options(exe, cluster_opts))
        {
            Ok(b) => {
                eprintln!(
                    "[serve] pool: {} {} workers, transport {}, replicas {}",
                    b.num_workers(),
                    if remote { "remote" } else { "forked" },
                    b.transport_kind().name(),
                    b.replicas()
                );
                ServeDaemon::start(Arc::new(b), opts)
            }
            Err(e) => {
                eprintln!("[serve] FATAL: cannot start the worker pool: {e}");
                return ExitCode::FAILURE;
            }
        }
    } else {
        ServeDaemon::start(make_backend(args), opts)
    };
    let mut daemon = match started {
        Ok(d) => d,
        Err(e) => {
            eprintln!("[serve] FATAL: cannot bind the serve port: {e}");
            return ExitCode::FAILURE;
        }
    };
    // Machine-readable announce, same contract as PARCCM_WORKER_LISTENING:
    // scripts scrape this line to learn the bound port.
    println!("PARCCM_SERVE_LISTENING {}", daemon.addr());
    let _ = std::io::Write::flush(&mut std::io::stdout());
    eprintln!(
        "[serve] accepting jobs on {} (max {} concurrent; stop with \
         `parccm cancel --at {} --shutdown`)",
        daemon.addr(),
        max_concurrent,
        daemon.addr()
    );
    daemon.wait();
    eprintln!("[serve] drained: {} job(s) served", daemon.tracker().jobs_served());
    ExitCode::SUCCESS
}

fn cmd_submit(args: &Args) -> ExitCode {
    let case_name = args.get("case").unwrap_or("A4");
    let Some(case) = Case::parse(case_name) else {
        eprintln!("unknown --case '{case_name}' (expected A1..A5)");
        return ExitCode::FAILURE;
    };
    // Same flag surface as `fig4`, so a submitted job is the batch run's
    // spec verbatim — that is what makes the dumps byte-identical.
    let spec = JobSpec {
        case,
        scenario: scenario_from(args),
        policy: table_policy_from(args),
        shards: args.get_usize("shards", 1),
        reduce: reduce_from(args),
        partial: partial_from(args),
    };
    let mut client = match connect_serve_client(args) {
        Ok(c) => c,
        Err(code) => return code,
    };
    match client.submit(&spec) {
        Ok(job) => {
            // Bare id on stdout: `JOB=$(parccm submit ...)` just works.
            println!("{job}");
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("submit failed: {e}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_status(args: &Args) -> ExitCode {
    let job = match job_arg(args) {
        Ok(j) => j,
        Err(code) => return code,
    };
    let mut client = match connect_serve_client(args) {
        Ok(c) => c,
        Err(code) => return code,
    };
    match client.status(job) {
        Ok(reply) => {
            println!("{reply}");
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("status failed: {e}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_fetch(args: &Args) -> ExitCode {
    let job = match job_arg(args) {
        Ok(j) => j,
        Err(code) => return code,
    };
    let mut client = match connect_serve_client(args) {
        Ok(c) => c,
        Err(code) => return code,
    };
    if args.flag("wait") {
        loop {
            let reply = match client.status(job) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("status failed while waiting: {e}");
                    return ExitCode::FAILURE;
                }
            };
            match reply.get("state").and_then(Json::as_str) {
                Some("queued") | Some("running") => {
                    std::thread::sleep(std::time::Duration::from_millis(200))
                }
                _ => break,
            }
        }
    }
    match client.fetch(job) {
        Ok(dump) => {
            match args.get("out") {
                Some(path) => {
                    // Exact bytes, no trailing newline: the file must be
                    // byte-comparable with a batch `--dump-skills` dump.
                    if let Err(e) = std::fs::write(path, dump.as_bytes()) {
                        eprintln!("cannot write {path}: {e}");
                        return ExitCode::FAILURE;
                    }
                    eprintln!("(job {job} skills -> {path})");
                }
                None => println!("{dump}"),
            }
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("fetch failed: {e}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_cancel(args: &Args) -> ExitCode {
    let mut client = match connect_serve_client(args) {
        Ok(c) => c,
        Err(code) => return code,
    };
    if args.flag("shutdown") {
        return match client.shutdown_daemon() {
            Ok(()) => {
                println!("shutdown acknowledged; daemon draining");
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("shutdown failed: {e}");
                ExitCode::FAILURE
            }
        };
    }
    let job = match job_arg(args) {
        Ok(j) => j,
        Err(code) => return code,
    };
    match client.cancel(job) {
        Ok(state) => {
            println!("job {job}: {state}");
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("cancel failed: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every dispatch-table row must carry coherent help: a unique name,
    /// a one-liner for the global help, and a usage block that leads with
    /// its own invocation. Guards satellite work on the subcommand table
    /// from rows drifting out of sync with their docs.
    #[test]
    fn subcommand_table_covers_help_and_dispatch() {
        let mut seen = std::collections::HashSet::new();
        for sub in SUBCOMMANDS {
            assert!(seen.insert(sub.name), "duplicate subcommand '{}'", sub.name);
            assert!(!sub.about.is_empty(), "'{}' has an empty about line", sub.name);
            assert!(
                sub.usage.starts_with(&format!("USAGE: parccm {}", sub.name)),
                "'{}' usage must lead with its own invocation, got: {}",
                sub.name,
                sub.usage
            );
        }
        // The serve-mode family ships alongside the batch commands.
        for name in ["serve", "submit", "status", "fetch", "cancel", "fig4", "events", "worker"] {
            assert!(
                SUBCOMMANDS.iter().any(|s| s.name == name),
                "missing subcommand '{name}'"
            );
        }
        // Exactly one hidden row: the worker child entry point.
        let hidden: Vec<&str> = SUBCOMMANDS.iter().filter(|s| s.hidden).map(|s| s.name).collect();
        assert_eq!(hidden, ["worker"]);
    }
}
