//! [`XlaBackend`] — the [`ComputeBackend`] implementation that runs the
//! AOT-lowered JAX/Pallas graphs via [`XlaService`].
//!
//! Workloads are padded up to the nearest artifact bucket:
//! * embedding lanes are already EMAX-padded throughout the crate;
//! * library/prediction rows pad with zeros + `*_valid = 0` masks (the
//!   graph pushes masked rows past `BIG`, pytest-verified);
//! * time indices of padded rows are large sentinels so Theiler windows
//!   can never collide with real rows;
//! * the neighbour count is a `k_mask` (first E+1 ones).
//!
//! The zero-copy [`CrossMapInput`] view gathers straight into the padded
//! device buffers (one pass, no intermediate library materialization) —
//! padding is the accelerator's serialization boundary, so these copies
//! are inherent to the offload, not task-assembly overhead.
//!
//! Workloads larger than every bucket fall back to the native backend
//! (logged once) — graceful degradation instead of a hot-path panic.

use std::sync::atomic::{AtomicBool, Ordering};

use crate::ccm::backend::{ComputeBackend, CrossMapInput, TaskArena};
use crate::native::NativeBackend;
use crate::runtime::manifest::ArtifactKind;
use crate::runtime::service::XlaService;
use crate::util::error::Result;
use crate::{EMAX, KMAX};

/// XLA-offload backend (thread-safe; shares one service pool).
pub struct XlaBackend {
    service: XlaService,
    fallback: NativeBackend,
    warned_fallback: AtomicBool,
}

impl XlaBackend {
    pub fn new(service: XlaService) -> XlaBackend {
        XlaBackend { service, fallback: NativeBackend, warned_fallback: AtomicBool::new(false) }
    }

    /// Start a service over `dir` and wrap it.
    pub fn from_dir(dir: &str, pool_size: usize) -> Result<XlaBackend> {
        Ok(XlaBackend::new(XlaService::start(dir, pool_size)?))
    }

    fn note_fallback(&self, what: &str, needed: usize) {
        if !self.warned_fallback.swap(true, Ordering::Relaxed) {
            eprintln!(
                "[parccm] warning: {what} needs {needed} rows, larger than every \
                 AOT bucket; falling back to the native backend (rebuild \
                 artifacts with bigger buckets to stay on XLA)"
            );
        }
    }

    fn k_mask(e: usize) -> Vec<f32> {
        let mut m = vec![0.0f32; KMAX];
        for v in m.iter_mut().take(e + 1) {
            *v = 1.0;
        }
        m
    }

    /// Pad `[rows, EMAX]` flat vectors to `bucket` rows.
    fn pad_vecs(vecs: &[f32], rows: usize, bucket: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; bucket * EMAX];
        out[..rows * EMAX].copy_from_slice(&vecs[..rows * EMAX]);
        out
    }

    /// Pad a scalar column to `bucket` with `fill`.
    fn pad_col(col: &[f32], bucket: usize, fill: f32) -> Vec<f32> {
        let mut out = vec![fill; bucket];
        out[..col.len()].copy_from_slice(col);
        out
    }

    fn valid_mask(real: usize, bucket: usize) -> Vec<f32> {
        let mut m = vec![0.0f32; bucket];
        for v in m.iter_mut().take(real) {
            *v = 1.0;
        }
        m
    }
}

impl ComputeBackend for XlaBackend {
    fn cross_map_into(&self, input: &CrossMapInput, arena: &mut TaskArena) -> f32 {
        let n = input.n_lib();
        let p = input.n_pred();
        let meta = match self
            .service
            .manifest()
            .bucket_for_rect(ArtifactKind::CrossMap, n, p)
        {
            Some(m) => m,
            None => {
                self.note_fallback("cross_map", n.max(p));
                return self.fallback.cross_map_into(input, arena);
            }
        };
        let (nb, pb) = (meta.n, meta.p);
        // gather the library rows straight into the padded device buffers
        let mut lib_vecs = vec![0.0f32; nb * EMAX];
        let mut lib_targets = vec![0.0f32; nb];
        let mut lib_times = vec![-1e9f32; nb];
        for (k, &row) in input.lib_rows.iter().enumerate() {
            lib_vecs[k * EMAX..(k + 1) * EMAX]
                .copy_from_slice(&input.vecs[row * EMAX..(row + 1) * EMAX]);
            lib_targets[k] = input.targets[row];
            lib_times[k] = input.times[row];
        }
        let inputs = vec![
            (lib_vecs, vec![nb as i64, EMAX as i64]),
            (Self::pad_vecs(input.vecs, p, pb), vec![pb as i64, EMAX as i64]),
            (Self::valid_mask(n, nb), vec![nb as i64]),
            (lib_targets, vec![nb as i64]),
            (Self::pad_col(input.targets, pb, 0.0), vec![pb as i64]),
            (Self::valid_mask(p, pb), vec![pb as i64]),
            (lib_times, vec![nb as i64]),
            (Self::pad_col(input.times, pb, -2e9), vec![pb as i64]),
            (Self::k_mask(input.e), vec![KMAX as i64]),
            (vec![input.theiler], vec![]),
        ];
        let out = self
            .service
            .execute(&meta.name, inputs)
            .expect("xla cross_map execution failed");
        arena.preds.clear();
        arena.preds.extend_from_slice(&out[1][..p]);
        out[0][0]
    }

    fn simplex_tail_into(
        &self,
        dvals: &[f32],
        tvals: &[f32],
        pred_targets: &[f32],
        e: usize,
        preds: &mut Vec<f32>,
    ) -> f32 {
        let p = pred_targets.len();
        let meta = match self.service.manifest().bucket_for(ArtifactKind::Simplex, p) {
            Some(m) => m,
            None => {
                self.note_fallback("simplex_tail", p);
                return self.fallback.simplex_tail_into(dvals, tvals, pred_targets, e, preds);
            }
        };
        let pb = meta.p;
        // pad panels with BIG distances / zero targets; padded rows are
        // excluded from the Pearson by pred_valid anyway.
        let mut dv = vec![crate::BIG; pb * KMAX];
        dv[..p * KMAX].copy_from_slice(&dvals[..p * KMAX]);
        let mut tv = vec![0.0f32; pb * KMAX];
        tv[..p * KMAX].copy_from_slice(&tvals[..p * KMAX]);
        let inputs = vec![
            (dv, vec![pb as i64, KMAX as i64]),
            (tv, vec![pb as i64, KMAX as i64]),
            (Self::pad_col(pred_targets, pb, 0.0), vec![pb as i64]),
            (Self::valid_mask(p, pb), vec![pb as i64]),
            (Self::k_mask(e), vec![KMAX as i64]),
        ];
        let out = self
            .service
            .execute(&meta.name, inputs)
            .expect("xla simplex execution failed");
        preds.clear();
        preds.extend_from_slice(&out[1][..p]);
        out[0][0]
    }

    fn distance_matrix(&self, vecs: &[f32], n: usize) -> Vec<f32> {
        let meta = match self.service.manifest().bucket_for(ArtifactKind::Distance, n) {
            Some(m) => m,
            None => {
                self.note_fallback("distance_matrix", n);
                return self.fallback.distance_matrix(vecs, n);
            }
        };
        let nb = meta.n;
        let padded = Self::pad_vecs(vecs, n, nb);
        let out = self
            .service
            .execute(
                &meta.name,
                vec![
                    (padded.clone(), vec![nb as i64, EMAX as i64]),
                    (padded, vec![nb as i64, EMAX as i64]),
                ],
            )
            .expect("xla distance execution failed");
        // extract the real [n, n] block from the padded [nb, nb] output
        let full = &out[0];
        let mut result = vec![0.0f32; n * n];
        for i in 0..n {
            result[i * n..(i + 1) * n].copy_from_slice(&full[i * nb..i * nb + n]);
        }
        result
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_mask_shape() {
        let m = XlaBackend::k_mask(3);
        assert_eq!(m.len(), KMAX);
        assert_eq!(m.iter().filter(|&&x| x == 1.0).count(), 4);
        assert_eq!(m[4], 0.0);
    }

    #[test]
    fn padding_helpers() {
        let v = XlaBackend::pad_col(&[1.0, 2.0], 4, -9.0);
        assert_eq!(v, vec![1.0, 2.0, -9.0, -9.0]);
        let m = XlaBackend::valid_mask(2, 4);
        assert_eq!(m, vec![1.0, 1.0, 0.0, 0.0]);
        let data = [7.0f32; 2 * EMAX];
        let vecs = XlaBackend::pad_vecs(&data, 2, 3);
        assert_eq!(vecs.len(), 3 * EMAX);
        assert!(vecs[2 * EMAX..].iter().all(|&x| x == 0.0));
    }
}
