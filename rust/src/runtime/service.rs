//! XLA execution service threads.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based and therefore `!Send`; a
//! client and its compiled executables must stay on the thread that
//! created them. [`XlaService`] spawns `pool_size` service threads, each
//! owning a full set of compiled executables; callers (engine executor
//! threads) submit [`ExecRequest`]s over a shared channel and block on a
//! per-request reply channel. With `pool_size > 1`, independent tasks'
//! XLA calls genuinely overlap.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::util::error::{anyhow, Context as _, Result};

use super::manifest::Manifest;
use super::xla;

/// One XLA invocation: named executable + positional inputs.
pub struct ExecRequest {
    /// Artifact name (e.g. `ccm_n512`).
    pub name: String,
    /// Positional inputs: flat f32 data + dims (empty dims = scalar).
    pub inputs: Vec<(Vec<f32>, Vec<i64>)>,
    /// Reply channel: flat f32 outputs, one per tuple element.
    pub reply: Sender<Result<Vec<Vec<f32>>>>,
}

/// Handle to the service thread pool. Cheap to clone; dropping the last
/// handle shuts the threads down.
#[derive(Clone)]
pub struct XlaService {
    tx: Sender<ExecRequest>,
    shared: Arc<ServiceShared>,
}

struct ServiceShared {
    pub manifest: Manifest,
    threads: Mutex<Vec<JoinHandle<()>>>,
    _keep_tx: Mutex<Option<Sender<ExecRequest>>>,
}

impl XlaService {
    /// Compile every artifact in `dir` on `pool_size` service threads.
    pub fn start(dir: impl Into<PathBuf>, pool_size: usize) -> Result<XlaService> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)?;
        let (tx, rx) = channel::<ExecRequest>();
        let rx = Arc::new(Mutex::new(rx));

        let mut threads = Vec::new();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        for i in 0..pool_size.max(1) {
            let rx = Arc::clone(&rx);
            let manifest = manifest.clone();
            let ready = ready_tx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("xla-service-{i}"))
                    .spawn(move || service_loop(manifest, rx, ready))
                    .expect("spawning xla service thread"),
            );
        }
        drop(ready_tx);
        // wait until every thread compiled its executables (or failed)
        for _ in 0..pool_size.max(1) {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("xla service thread died during startup"))??;
        }
        Ok(XlaService {
            tx: tx.clone(),
            shared: Arc::new(ServiceShared {
                manifest,
                threads: Mutex::new(threads),
                _keep_tx: Mutex::new(Some(tx)),
            }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.shared.manifest
    }

    /// Execute `name` with `inputs`; blocks until the reply arrives.
    pub fn execute(&self, name: &str, inputs: Vec<(Vec<f32>, Vec<i64>)>) -> Result<Vec<Vec<f32>>> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(ExecRequest { name: name.to_string(), inputs, reply: reply_tx })
            .map_err(|_| anyhow!("xla service is down"))?;
        reply_rx.recv().map_err(|_| anyhow!("xla service dropped the request"))?
    }

    /// Explicit shutdown (also happens on drop of the last handle).
    pub fn shutdown(&self) {
        self.shared._keep_tx.lock().unwrap().take();
        let mut threads = self.shared.threads.lock().unwrap();
        for t in threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn service_loop(
    manifest: Manifest,
    rx: Arc<Mutex<Receiver<ExecRequest>>>,
    ready: Sender<Result<()>>,
) {
    // Compile everything on THIS thread (client is thread-bound).
    let built = (|| -> Result<(xla::PjRtClient, HashMap<String, xla::PjRtLoadedExecutable>)> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = HashMap::new();
        for a in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(&a.path)
                .with_context(|| format!("parsing {}", a.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", a.name))?;
            exes.insert(a.name.clone(), exe);
        }
        Ok((client, exes))
    })();

    let (_client, exes) = match built {
        Ok(pair) => {
            let _ = ready.send(Ok(()));
            pair
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    loop {
        // hold the lock only while receiving, not while executing
        let req = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let req = match req {
            Ok(r) => r,
            Err(_) => return, // all senders dropped -> shutdown
        };
        let result = run_one(&exes, &req);
        let _ = req.reply.send(result);
    }
}

fn run_one(
    exes: &HashMap<String, xla::PjRtLoadedExecutable>,
    req: &ExecRequest,
) -> Result<Vec<Vec<f32>>> {
    let exe = exes
        .get(&req.name)
        .ok_or_else(|| anyhow!("unknown artifact '{}'", req.name))?;
    let literals: Vec<xla::Literal> = req
        .inputs
        .iter()
        .map(|(data, dims)| -> Result<xla::Literal> {
            if dims.is_empty() {
                Ok(xla::Literal::scalar(data[0]))
            } else {
                Ok(xla::Literal::vec1(data).reshape(dims)?)
            }
        })
        .collect::<Result<_>>()?;
    let out = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True: unwrap the tuple.
    let parts = out.to_tuple()?;
    parts
        .into_iter()
        .map(|lit| Ok(lit.to_vec::<f32>()?))
        .collect()
}

#[cfg(test)]
mod tests {
    // End-to-end service tests live in rust/tests/integration_runtime.rs
    // (they need `make artifacts` to have run).
}
