//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime.

use std::path::{Path, PathBuf};

use crate::util::error::{bail, Context as _, Result};

use crate::util::json::Json;
use crate::{EMAX, KMAX};

/// Kind of lowered graph (matches `aot.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Full per-subsample cross-map (distances -> topk -> simplex -> rho).
    CrossMap,
    /// Raw pairwise squared-distance matrix.
    Distance,
    /// Simplex + Pearson tail over pre-gathered neighbour panels.
    Simplex,
}

/// One lowered HLO module.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: ArtifactKind,
    /// Library / row bucket size.
    pub n: usize,
    /// Prediction bucket size.
    pub p: usize,
    /// HLO text path.
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let json = Json::parse(&text).context("parsing manifest.json")?;

        let emax = json.get("emax").and_then(Json::as_usize).unwrap_or(0);
        let kmax = json.get("kmax").and_then(Json::as_usize).unwrap_or(0);
        if emax != EMAX || kmax != KMAX {
            bail!(
                "artifact contract mismatch: manifest EMAX={emax}/KMAX={kmax}, \
                 binary expects {EMAX}/{KMAX} — rebuild with `make artifacts`"
            );
        }

        let mut artifacts = Vec::new();
        for a in json
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts'")?
        {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .context("artifact missing name")?
                .to_string();
            let kind = match a.get("kind").and_then(Json::as_str) {
                Some("cross_map") => ArtifactKind::CrossMap,
                Some("distance") => ArtifactKind::Distance,
                Some("simplex") => ArtifactKind::Simplex,
                other => bail!("artifact {name}: unknown kind {other:?}"),
            };
            let n = a.get("n").and_then(Json::as_usize).context("artifact missing n")?;
            let p = a.get("p").and_then(Json::as_usize).context("artifact missing p")?;
            let file = a.get("file").and_then(Json::as_str).context("artifact missing file")?;
            let path = dir.join(file);
            if !path.exists() {
                bail!("artifact file missing: {}", path.display());
            }
            artifacts.push(ArtifactMeta { name, kind, n, p, path });
        }
        if artifacts.is_empty() {
            bail!("manifest lists no artifacts");
        }
        Ok(Manifest { artifacts })
    }

    /// Smallest bucket of `kind` with `n >= needed`.
    pub fn bucket_for(&self, kind: ArtifactKind, needed: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.n >= needed)
            .min_by_key(|a| a.n)
    }

    /// Cheapest rectangular bucket fitting `n_needed` library rows and
    /// `p_needed` prediction rows (minimizing padded distance work n*p) —
    /// cross-map buckets are rectangular, see aot.py.
    pub fn bucket_for_rect(
        &self,
        kind: ArtifactKind,
        n_needed: usize,
        p_needed: usize,
    ) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.n >= n_needed && a.p >= p_needed)
            .min_by_key(|a| a.n * a.p)
    }

    /// Largest bucket of `kind`.
    pub fn max_bucket(&self, kind: ArtifactKind) -> Option<usize> {
        self.artifacts.iter().filter(|a| a.kind == kind).map(|a| a.n).max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = std::env::temp_dir().join("parccm_manifest_ok");
        write_manifest(
            &dir,
            r#"{"emax":8,"kmax":11,"big":1e30,"artifacts":[
                {"name":"ccm_n256","kind":"cross_map","file":"ccm_n256.hlo.txt","n":256,"p":256}
            ]}"#,
        );
        std::fs::write(dir.join("ccm_n256.hlo.txt"), "HloModule fake").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        assert_eq!(m.artifacts[0].kind, ArtifactKind::CrossMap);
        assert_eq!(m.bucket_for(ArtifactKind::CrossMap, 100).unwrap().n, 256);
        assert!(m.bucket_for(ArtifactKind::CrossMap, 300).is_none());
        assert_eq!(m.max_bucket(ArtifactKind::CrossMap), Some(256));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_contract_mismatch() {
        let dir = std::env::temp_dir().join("parccm_manifest_bad");
        write_manifest(&dir, r#"{"emax":4,"kmax":11,"artifacts":[]}"#);
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("contract mismatch"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_missing_file() {
        let dir = std::env::temp_dir().join("parccm_manifest_missing");
        write_manifest(
            &dir,
            r#"{"emax":8,"kmax":11,"artifacts":[
                {"name":"x","kind":"distance","file":"nope.hlo.txt","n":256,"p":256}
            ]}"#,
        );
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bucket_selection_prefers_smallest_fit() {
        let dir = std::env::temp_dir().join("parccm_manifest_buckets");
        write_manifest(
            &dir,
            r#"{"emax":8,"kmax":11,"artifacts":[
                {"name":"a","kind":"distance","file":"a.hlo.txt","n":256,"p":256},
                {"name":"b","kind":"distance","file":"b.hlo.txt","n":1024,"p":1024},
                {"name":"c","kind":"distance","file":"c.hlo.txt","n":512,"p":512}
            ]}"#,
        );
        for f in ["a.hlo.txt", "b.hlo.txt", "c.hlo.txt"] {
            std::fs::write(dir.join(f), "HloModule fake").unwrap();
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.bucket_for(ArtifactKind::Distance, 257).unwrap().n, 512);
        assert_eq!(m.bucket_for(ArtifactKind::Distance, 512).unwrap().n, 512);
        assert_eq!(m.bucket_for(ArtifactKind::Distance, 1).unwrap().n, 256);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
