//! PJRT/XLA client shim.
//!
//! The real AOT execution path links a PJRT CPU client (the `xla` crate in
//! the original build image). This offline build ships the same API
//! surface as a stub whose constructors fail with a descriptive error:
//! [`super::service::XlaService::start`] then returns `Err`, and every
//! caller already degrades to [`crate::native::NativeBackend`] (see
//! `make_backend` in `main.rs` and the bench `common` module). Swapping a
//! real client back in means replacing only this module — the service,
//! backend, and manifest layers are written against this surface.

use std::path::Path;

use crate::util::error::{anyhow, Result};

const UNAVAILABLE: &str =
    "PJRT runtime not linked in this build (offline stub); use the native backend \
     or rebuild with a real XLA client in src/runtime/xla.rs";

/// Stub PJRT CPU client. [`PjRtClient::cpu`] always fails in this build.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(anyhow!("{UNAVAILABLE}"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(anyhow!("{UNAVAILABLE}"))
    }
}

/// Parsed HLO module text (stub: never constructed successfully).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(anyhow!("{UNAVAILABLE}"))
    }
}

/// An XLA computation wrapping an HLO proto.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable resident on a client.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(anyhow!("{UNAVAILABLE}"))
    }
}

/// Device-resident output buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(anyhow!("{UNAVAILABLE}"))
    }
}

/// Host-side tensor literal.
pub struct Literal;

impl Literal {
    pub fn scalar(_value: f32) -> Literal {
        Literal
    }

    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(anyhow!("{UNAVAILABLE}"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(anyhow!("{UNAVAILABLE}"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(anyhow!("{UNAVAILABLE}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_but_gracefully() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(err.to_string().contains("PJRT runtime not linked"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
