//! The AOT runtime bridge: load `artifacts/*.hlo.txt` (lowered once from
//! the JAX/Pallas graphs by `make artifacts`) and execute them on the PJRT
//! CPU client from the Rust hot path. Python never runs here.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (shape buckets,
//!   EMAX/KMAX contract).
//! * [`service`] — the `xla` crate's client is `Rc`-based (not `Send`), so
//!   executables live on dedicated service threads; tasks talk to them
//!   through channels. One service thread per pool slot.
//! * [`backend`] — [`XlaBackend`] implements the
//!   [`crate::ccm::backend::ComputeBackend`] contract by padding workloads
//!   to the nearest artifact bucket (masks keep padding out of the
//!   numerics — the contract verified by pytest on the Python side and by
//!   the native/XLA equivalence tests here).

pub mod backend;
pub mod manifest;
pub mod service;
pub mod xla;

pub use backend::XlaBackend;
pub use manifest::{ArtifactMeta, Manifest};
pub use service::XlaService;

/// Default artifacts directory (relative to the repo root / CWD).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// True if an artifacts directory with a manifest exists.
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}
