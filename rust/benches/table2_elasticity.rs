//! Table 2 / Fig. 5 reproduction: runtime elasticity with respect to L,
//! E and tau, single-threaded (Case A1 / B-series) vs fully parallel
//! (Case A5).
//!
//! Paper shape to reproduce:
//! * doubling L multiplies single-threaded time ~4x but parallel only
//!   ~1.1x (the distance indexing table absorbs the L growth);
//! * doubling E or tau is nearly free for the parallel version;
//! * doubling tau costs ~1.13x single-threaded.
//!
//! Run: `cargo bench --bench table2_elasticity [-- --full --repeats N]`

mod common;

use std::sync::Arc;

use parccm::bench::report::{Row, TablePrinter};
use parccm::ccm::driver::{Case, RunSpec};
use parccm::engine::Deploy;
use parccm::util::stats;

fn main() {
    let args = common::args();
    let base = common::scenario(&args);
    let backend = common::backend(&args);
    let repeats = common::repeats(&args, 3);
    let cluster = Deploy::Cluster {
        workers: args.get_usize("workers", 5),
        cores_per_worker: args.get_usize("cores", 4),
    };
    let (x, y) = common::workload(&base);
    let (e0, t0, l0) = (1usize, 1usize, base.ls[0]);

    println!(
        "table2: series={} r={} varying L over {:?}, E over {:?}, tau over {:?} (repeats={repeats})",
        base.series_len, base.r, base.ls, base.es, base.taus
    );

    let mut table = TablePrinter::new("Table 2 / Fig 5 — elasticity (mean s; ratio vs smallest)");
    let mut measure = |_label: String, e: usize, tau: usize, l: usize| -> (f64, f64) {
        let mut s = base.clone();
        s.es = vec![e];
        s.taus = vec![tau];
        s.ls = vec![l];
        let mut single = Vec::new();
        let mut par = Vec::new();
        for _ in 0..repeats {
            single.push(
                RunSpec::new(Case::A1, &s, &y, &x).run(Arc::clone(&backend)).report.measured_wall_s,
            );
            par.push(
                RunSpec::new(Case::A5, &s, &y, &x)
                    .deploy(cluster.clone())
                    .run(Arc::clone(&backend))
                    .report
                    .sim_makespan_s,
            );
        }
        (stats::mean(&single), stats::mean(&par))
    };

    let sweep = |name: &str,
                 values: &[usize],
                 f: &mut dyn FnMut(usize) -> (f64, f64),
                 table: &mut TablePrinter| {
        let mut first: Option<(f64, f64)> = None;
        for &v in values {
            let (s, p) = f(v);
            let (fs, fp) = *first.get_or_insert((s, p));
            table.push(
                Row::new(format!("{name}={v}"))
                    .cell("single_s", s)
                    .cell("parallel_s", p)
                    .cell("single_ratio", s / fs)
                    .cell("parallel_ratio", p / fp),
            );
        }
    };

    sweep("L", &base.ls.clone(), &mut |l| measure(format!("L{l}"), e0, t0, l), &mut table);
    sweep("E", &base.es.clone(), &mut |e| measure(format!("E{e}"), e, t0, l0), &mut table);
    sweep("tau", &base.taus.clone(), &mut |t| measure(format!("t{t}"), e0, t, l0), &mut table);

    table.print();
    let _ = table.save("results/bench_table2.json");
    let _ = table.save("BENCH_table2.json");
    println!("\n(paper: L-doubling -> 4.06x single / 1.11x parallel; tau-doubling -> 1.13x single)");
}
