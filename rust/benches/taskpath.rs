//! Task-path microbench (ISSUE 1 acceptance gate): cost of assembling the
//! per-subsample cross-map task, owned-copy (the pre-zero-copy layout:
//! every task deep-copied the n*EMAX prediction manifold plus two
//! length-n columns and materialized the library into fresh `Vec`s)
//! versus zero-copy (borrowed [`CrossMapInput`] view + arena gather), the
//! wire-codec cost of a problem broadcast (v6 binary frame vs legacy JSON
//! line, with hard asserts that binary wins on bytes and on encode+decode
//! time), and the broadcast footprint of the full versus truncated
//! distance table.
//!
//! Acceptance: >= 5x reduction in per-task assembly time at n=1000, r=25,
//! and `O(n * P)` truncated broadcast bytes.
//!
//! Run: `cargo bench --bench taskpath [-- --n 1000 --r 25]`
//! Emits `BENCH_taskpath.json` (and `results/BENCH_taskpath.json`).

mod common;

use parccm::bench::report::{Row, TablePrinter};
use parccm::bench::Bencher;
use parccm::ccm::backend::{ComputeBackend, TaskArena};
use parccm::ccm::binwire;
use parccm::ccm::cluster::{problem_payload, problem_wire_id};
use parccm::ccm::params::CcmParams;
use parccm::ccm::pipeline::CcmProblem;
use parccm::ccm::subsample::{draw_samples, LibrarySample};
use parccm::ccm::table::DistanceTable;
use parccm::native::NativeBackend;
use parccm::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
use parccm::util::rng::Rng;
use parccm::EMAX;

/// The seed repo's task assembly, reproduced verbatim for comparison:
/// gather the library into fresh Vecs AND deep-copy the entire
/// prediction side (manifold vectors, targets, recomputed times).
fn owned_copy_assembly(problem: &CcmProblem, sample: &LibrarySample) -> usize {
    let l = sample.rows.len();
    let mut lib_vecs = Vec::with_capacity(l * EMAX);
    let mut lib_targets = Vec::with_capacity(l);
    let mut lib_times = Vec::with_capacity(l);
    for &row in &sample.rows {
        lib_vecs.extend_from_slice(problem.emb.point(row));
        lib_targets.push(problem.targets[row]);
        lib_times.push(problem.emb.time_of(row) as f32);
    }
    let pred_vecs = problem.emb.vecs.clone();
    let pred_targets = problem.targets.clone();
    let pred_times: Vec<f32> =
        (0..problem.emb.n).map(|i| problem.emb.time_of(i) as f32).collect();
    std::hint::black_box(&pred_vecs);
    std::hint::black_box(&pred_targets);
    std::hint::black_box(&pred_times);
    lib_vecs.len() + lib_targets.len() + lib_times.len() + pred_vecs.len()
}

fn main() {
    let args = common::args();
    let n_series = args.get_usize("n", common::default_n(&args, 1000, 256));
    let r = args.get_usize("r", common::default_n(&args, 25, 5));
    let (x, y) = coupled_logistic(n_series, CoupledLogisticParams::default());
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let n = problem.emb.n;
    let samples = draw_samples(&Rng::new(1), CcmParams::new(2, 1, n / 4), n, r);
    let bencher = Bencher::new().warmup(2).samples(args.get_usize("repeats", 7));

    let mut table = TablePrinter::new(format!("taskpath (n={n}, r={r})"));

    // -- task assembly: owned-copy vs zero-copy ------------------------
    let owned = bencher.run("owned-copy task assembly (r tasks)", || {
        let mut acc = 0usize;
        for s in &samples {
            acc += owned_copy_assembly(&problem, s);
        }
        acc
    });
    let mut arena = TaskArena::new();
    let zero = bencher.run("zero-copy task assembly (r tasks)", || {
        let mut acc = 0usize;
        for s in &samples {
            let input = problem.input_for(s);
            arena.gather_library(&input);
            acc += input.lib_rows.len() + arena.lib_vecs.len();
        }
        acc
    });
    let speedup = owned.mean_s / zero.mean_s.max(1e-12);
    table.push(
        Row::new("assembly_owned_copy").cell("mean_s", owned.mean_s).cell("std_s", owned.std_s),
    );
    table.push(
        Row::new("assembly_zero_copy").cell("mean_s", zero.mean_s).cell("std_s", zero.std_s),
    );
    table.push(Row::new("assembly_speedup").cell("x", speedup).cell("target_x", 5.0));

    // -- end-to-end cross-map: fresh allocations vs arena reuse --------
    let backend = NativeBackend;
    let fresh = bencher.run("cross_map, fresh buffers per task", || {
        let mut acc = 0.0f32;
        for s in &samples {
            acc += backend.cross_map(&problem.input_for(s)).rho;
        }
        acc
    });
    let mut cm_arena = TaskArena::new();
    let reused = bencher.run("cross_map, arena-reused buffers", || {
        let mut acc = 0.0f32;
        for s in &samples {
            acc += backend.cross_map_into(&problem.input_for(s), &mut cm_arena);
        }
        acc
    });
    table.push(Row::new("cross_map_fresh").cell("mean_s", fresh.mean_s).cell("std_s", fresh.std_s));
    table.push(
        Row::new("cross_map_arena").cell("mean_s", reused.mean_s).cell("std_s", reused.std_s),
    );
    table.push(
        Row::new("cross_map_arena_gain")
            .cell("x", fresh.mean_s / reused.mean_s.max(1e-12)),
    );

    // -- wire codecs: v6 binary frames vs legacy JSON lines ------------
    // the same problem broadcast through both encoders and decoders; the
    // ship_b cells are true on-wire sizes (line + newline vs frame body +
    // length prefix). The binary codec must beat JSON on bytes AND on
    // encode+decode time — both hard-asserted, since that pair is the
    // whole case for wire v6.
    {
        let times: Vec<f32> = (0..n).map(|i| problem.emb.time_of(i) as f32).collect();
        let id = problem_wire_id(&problem.emb.vecs, &problem.targets, &times);
        let json_line = problem_payload(id, &problem.emb.vecs, &problem.targets, &times);
        let bin_frame = binwire::encode_problem(id, &problem.emb.vecs, &problem.targets, &times);
        let json_ship = json_line.len() + 1;
        let bin_ship = bin_frame.len() + 4;
        let ej = bencher.run("wire encode json", || {
            problem_payload(id, &problem.emb.vecs, &problem.targets, &times).len()
        });
        let eb = bencher.run("wire encode binary", || {
            binwire::encode_problem(id, &problem.emb.vecs, &problem.targets, &times).len()
        });
        let dj = bencher.run("wire decode json", || {
            let parsed = parccm::util::json::Json::parse(&json_line)
                .expect("legacy broadcast line parses");
            parsed.get("vecs").and_then(|v| v.as_arr()).map(|a| a.len()).unwrap_or(0)
        });
        let db = bencher.run("wire decode binary", || {
            match binwire::decode(&bin_frame).expect("v6 frame decodes") {
                binwire::BinMsg::Broadcast(binwire::Broadcast::Problem { vecs, .. }) => vecs.len(),
                _ => panic!("problem frame decoded to the wrong variant"),
            }
        });
        table.push(
            Row::new("wire_json")
                .cell("encode_s", ej.mean_s)
                .cell("decode_s", dj.mean_s)
                .cell("ship_b", json_ship as f64),
        );
        table.push(
            Row::new("wire_binary")
                .cell("encode_s", eb.mean_s)
                .cell("decode_s", db.mean_s)
                .cell("ship_b", bin_ship as f64)
                .cell("cut_x", json_ship as f64 / bin_ship as f64),
        );
        assert!(
            bin_ship < json_ship,
            "binary problem frame ({bin_ship} B) must undercut the JSON line ({json_ship} B)"
        );
        assert!(
            eb.mean_s + db.mean_s < ej.mean_s + dj.mean_s,
            "binary encode+decode ({:.2e}s) must beat JSON ({:.2e}s)",
            eb.mean_s + db.mean_s,
            ej.mean_s + dj.mean_s
        );
    }

    // -- broadcast bytes: full vs truncated table ----------------------
    for min_l in [n / 8, n / 4, n / 2] {
        let prefix = DistanceTable::auto_prefix(n, min_l);
        let full_bytes = n * (n - 1) * 4 + n * EMAX * 4;
        let trunc = DistanceTable::build_truncated(&problem.emb, prefix);
        table.push(
            Row::new(format!("table_bytes_minL_{min_l}"))
                .cell("full_b", full_bytes as f64)
                .cell("truncated_b", trunc.size_bytes() as f64)
                .cell("prefix", prefix as f64)
                .cell("cut_x", full_bytes as f64 / trunc.size_bytes() as f64),
        );
    }

    table.print();
    println!(
        "\nassembly speedup {speedup:.1}x (acceptance target: >= 5x at n=1000, r=25)"
    );
    let _ = table.save("results/BENCH_taskpath.json");
    let _ = table.save("BENCH_taskpath.json");
}
