//! Shared bench utilities (workload + CLI conventions).
//!
//! All benches accept `-- --full` to run the paper-scale baseline scenario
//! (series 4000, r 500); the default is the 1-core-scaled variant from
//! `Scenario::scaled_baseline`, and `-- --tiny` shrinks to the smoke
//! scenario so CI can *execute* every bench (not just compile it) in
//! seconds while still emitting real `BENCH_*.json` artifacts.
//! `--backend native|xla` picks the compute backend.

use std::sync::Arc;

use parccm::ccm::backend::ComputeBackend;
use parccm::ccm::params::Scenario;
use parccm::native::NativeBackend;
use parccm::runtime::{artifacts_available, XlaBackend, DEFAULT_ARTIFACTS_DIR};
use parccm::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
use parccm::util::cli::Args;

pub fn args() -> Args {
    Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))
}

pub fn scenario(args: &Args) -> Scenario {
    let mut s = if args.flag("full") {
        Scenario::paper_baseline()
    } else if args.flag("tiny") {
        Scenario::smoke()
    } else {
        Scenario::scaled_baseline()
    };
    s.seed = args.get_u64("seed", s.seed);
    s
}

/// Problem-size default honouring `--tiny` (benches that size themselves
/// with `--n` instead of a full scenario).
pub fn default_n(args: &Args, normal: usize, tiny: usize) -> usize {
    if args.flag("tiny") {
        tiny
    } else {
        normal
    }
}

pub fn workload(s: &Scenario) -> (Vec<f32>, Vec<f32>) {
    coupled_logistic(s.series_len, CoupledLogisticParams::default())
}

/// Default to the native backend: the scheduling comparisons the paper
/// makes (table vs brute, async vs sync, topology width) are backend-
/// independent, and native keeps bench turnaround short on 1 core. Pass
/// `-- --backend xla` to cost the AOT/PJRT path (microbench does both).
pub fn backend(args: &Args) -> Arc<dyn ComputeBackend> {
    let dir = args.get("artifacts").unwrap_or(DEFAULT_ARTIFACTS_DIR).to_string();
    let choice = args.get("backend").unwrap_or("native");
    let _ = artifacts_available(&dir);
    if choice == "xla" {
        if let Ok(b) = XlaBackend::from_dir(&dir, args.get_usize("xla-pool", 1)) {
            eprintln!("[bench] backend: xla");
            return Arc::new(b);
        }
        eprintln!("[bench] xla unavailable, falling back to native");
    } else {
        eprintln!("[bench] backend: native");
    }
    Arc::new(NativeBackend)
}

pub fn repeats(args: &Args, default: usize) -> usize {
    args.get_usize("repeats", default)
}
