//! Fig. 4 reproduction: average computation time of implementation levels
//! A1–A5, submitted in Local mode vs Cluster ("Yarn") mode, on the
//! baseline scenario — plus the rEDM external comparator of §4.1.
//!
//! Paper shape to reproduce:
//! * Yarn mode ≪ Local mode for the engine cases;
//! * A5 is on the order of 1% of A1 on the cluster topology;
//! * the distance indexing table (A4/A5 vs A2/A3) cuts > 80%;
//! * async (A3 vs A2, A5 vs A4) helps only where cores are idle;
//! * A5 beats the rEDM-style sequential baseline by ~an order of
//!   magnitude on the 5x4 cluster.
//!
//! Run: `cargo bench --bench fig4_cases [-- --full --backend xla --repeats N]`

mod common;

use std::sync::Arc;

use parccm::baseline::{redm_ccm, RedmConfig};
use parccm::bench::report::{Row, TablePrinter};
use parccm::bench::Bencher;
use parccm::ccm::driver::{Case, RunSpec};
use parccm::engine::Deploy;
use parccm::util::stats;

fn main() {
    let args = common::args();
    let scenario = common::scenario(&args);
    let backend = common::backend(&args);
    let repeats = common::repeats(&args, 3);
    let cluster = Deploy::Cluster {
        workers: args.get_usize("workers", 5),
        cores_per_worker: args.get_usize("cores", 4),
    };
    let local = Deploy::Local { cores: args.get_usize("local-cores", 4) };
    let (x, y) = common::workload(&scenario);

    println!(
        "fig4: series={} r={} L={:?} E={:?} tau={:?} repeats={repeats}",
        scenario.series_len, scenario.r, scenario.ls, scenario.es, scenario.taus
    );

    let mut table = TablePrinter::new("Fig 4 — average computation time (s), Local vs Yarn");
    let mut a1_yarn = f64::NAN;
    let mut a2_yarn = f64::NAN;
    for case in Case::ALL {
        let mut local_s = Vec::new();
        let mut yarn_s = Vec::new();
        let mut wall_s = Vec::new();
        for _ in 0..repeats {
            // one real execution, two DES topologies (exact — numerics are
            // deploy-independent)
            let (_skills, reports) = RunSpec::new(case, &scenario, &y, &x)
                .run_multi(&[local.clone(), cluster.clone()], Arc::clone(&backend));
            local_s.push(reports[0].sim_makespan_s);
            yarn_s.push(reports[1].sim_makespan_s);
            wall_s.push(reports[1].measured_wall_s);
        }
        let yarn_mean = stats::mean(&yarn_s);
        if case == Case::A1 {
            a1_yarn = yarn_mean;
        }
        if case == Case::A2 {
            a2_yarn = yarn_mean;
        }
        table.push(
            Row::new(format!("{} {}", case.name(), case.description()))
                .cell("local_s", stats::mean(&local_s))
                .cell("yarn_s", yarn_mean)
                .cell("yarn_std", stats::stddev(&yarn_s))
                .cell("measured_s", stats::mean(&wall_s))
                .cell("vs_A1", yarn_mean / a1_yarn),
        );
    }

    // §4.1 external comparator: sequential rEDM-style run over the grid.
    let redm = Bencher::new().quiet(true).warmup(0).samples(repeats).run("redm", || {
        let mut total = 0usize;
        for combo in scenario.combos() {
            let rows = redm_ccm(
                &y,
                &x,
                &RedmConfig {
                    params: combo,
                    r: scenario.r,
                    theiler: scenario.theiler as f32,
                    seed: scenario.seed,
                },
            );
            total += rows.len();
        }
        total
    });
    table.push(
        Row::new("rEDM-style sequential baseline")
            .cell("local_s", redm.mean_s)
            .cell("yarn_s", redm.mean_s)
            .cell("yarn_std", redm.std_s)
            .cell("measured_s", redm.mean_s)
            .cell("vs_A1", redm.mean_s / a1_yarn),
    );

    table.print();
    let _ = table.save("results/bench_fig4.json");
    let _ = table.save("BENCH_fig4.json");

    println!("\nshape checks (paper expectations):");
    let a5 = table.rows[4].cells[1].1;
    let a4 = table.rows[3].cells[1].1;
    let a3 = table.rows[2].cells[1].1;
    println!(
        "  A5/A1 = {:.3}% (paper ~1.2%)   table cut (A4 vs A2) = {:.1}% (paper >80%)",
        100.0 * a5 / a1_yarn,
        100.0 * (1.0 - a4 / a2_yarn)
    );
    println!(
        "  async gain on cluster (A3 vs A2) = {:.1}%   rEDM/A5 = {:.1}x (paper ~15x)",
        100.0 * (1.0 - a3 / a2_yarn),
        redm.mean_s / a5
    );
}
