//! Cluster-runtime bench (ISSUE 3 acceptance support): what the transport
//! and the replication factor cost on the real wire.
//!
//! * task round-trip latency: one index-only cross-map task through a
//!   real worker process, pipe vs TCP loopback (same wire protocol — the
//!   delta is pure transport overhead);
//! * replica ship accounting: broadcast bytes/ships actually written for
//!   a sharded workload at `--replicas 1` vs `2` (the eager-copy cost
//!   that buys zero-re-ship requeue on worker death);
//! * straggler-defense overhead: the TCP round trip with the lease knobs
//!   on (`--speculate-factor` + `--task-deadline-secs`) — the price of
//!   per-task lease bookkeeping and deadline-bounded recv polling on a
//!   healthy pool, with the defense counters recorded as cells;
//! * wire-encoding cost: the TCP round trip against a stock worker (v6
//!   binary frames) vs a doctored `PARCCM_TEST_HELLO_V=5` worker (pinned
//!   legacy JSON lines) — bit-identical results, with hard asserts that
//!   the binary wire's broadcast and result-ingress bytes undercut JSON;
//! * result-ingress accounting: the same sharded A4 case under
//!   `--reduce driver` (raw prediction rows come back) vs
//!   `--reduce worker` (six-sum partials come back) — the wire-v5
//!   shuffle-stage reduce this crate exists to demonstrate. The byte
//!   cells are informational (only `_s` cells gate); the bench hard-
//!   asserts worker-reduce ingress is strictly below driver-reduce.
//!
//! Run: `cargo bench --bench cluster [-- --tiny | --full]`
//! Emits `BENCH_cluster.json` (and `results/BENCH_cluster.json`).

mod common;

use std::sync::Arc;

use parccm::bench::report::{Row, TablePrinter};
use parccm::bench::Bencher;
use parccm::ccm::backend::{ComputeBackend, TaskArena};
use parccm::ccm::cluster::{ClusterBackend, ClusterOptions, TEST_HELLO_V_ENV};
use parccm::ccm::driver::{Case, ReduceMode, RunSpec, TablePolicy};
use parccm::ccm::params::{CcmParams, Scenario};
use parccm::ccm::pipeline::CcmProblem;
use parccm::ccm::subsample::draw_samples;
use parccm::ccm::table::DistanceTable;
use parccm::ccm::transport::TransportKind;
use parccm::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
use parccm::util::rng::Rng;

fn spawn(kind: TransportKind, workers: usize, replicas: usize) -> ClusterBackend {
    ClusterBackend::with_options(
        env!("CARGO_BIN_EXE_parccm"),
        ClusterOptions { transport: kind, workers, replicas, ..ClusterOptions::default() },
    )
    .expect("spawning worker processes")
}

fn main() {
    let args = common::args();
    let n = common::default_n(&args, 600, 200);
    let bencher = Bencher::new().warmup(1).samples(common::repeats(&args, 3));
    let mut table = TablePrinter::new(format!("cluster transports & replication (n={n})"));

    let (x, y) = coupled_logistic(n, CoupledLogisticParams::default());
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let samples = draw_samples(&Rng::new(11), CcmParams::new(2, 1, n / 4), problem.emb.n, 1);
    let input = problem.input_for(&samples[0]);

    // -- task round-trip latency, pipe vs tcp ---------------------------
    // one worker so every task is a strict request/response on one link;
    // the broadcast ships once during warmup, so steady-state numbers are
    // the index-only task + preds reply round trip
    let mut rtt = Vec::new();
    for kind in [TransportKind::Pipe, TransportKind::Tcp] {
        let pb = spawn(kind, 1, 1);
        let mut arena = TaskArena::new();
        let res = bencher.run(&format!("{} cross_map round-trip", kind.name()), || {
            pb.cross_map_into(&input, &mut arena)
        });
        assert_eq!(pb.run_counters().respawns, 0, "bench must not hide worker churn");
        rtt.push((kind, res.mean_s));
    }
    let pipe_s = rtt[0].1;
    for (kind, mean_s) in &rtt {
        table.push(
            Row::new(format!("rtt_{}", kind.name()))
                .cell("task_s", *mean_s)
                .cell("vs_pipe_x", *mean_s / pipe_s.max(1e-12)),
        );
    }

    // -- wire encodings on a real pool: v6 binary vs pinned JSON --------
    // same strict single-worker TCP round trip twice: once on a stock
    // worker (negotiates the v6 binary frames) and once on a doctored
    // worker (TEST_HELLO_V_ENV=5) whose connection pins the legacy JSON
    // line wire. Results are bit-identical; the rows record what each
    // encoding costs — broadcast footprint (ships once during warmup),
    // accepted result-frame bytes, and the round-trip time.
    {
        let mut wire = Vec::new();
        for (label, env) in [
            ("wire_binary", Vec::new()),
            ("wire_json", vec![(TEST_HELLO_V_ENV.to_string(), "5".to_string())]),
        ] {
            let pb = ClusterBackend::with_options(
                env!("CARGO_BIN_EXE_parccm"),
                ClusterOptions {
                    transport: TransportKind::Tcp,
                    workers: 1,
                    replicas: 1,
                    worker_env: env,
                    ..ClusterOptions::default()
                },
            )
            .expect("spawning worker processes");
            let mut arena = TaskArena::new();
            let res = bencher.run(&format!("{label} cross_map round-trip"), || {
                pb.cross_map_into(&input, &mut arena)
            });
            let c = pb.run_counters();
            assert_eq!(c.respawns, 0, "{label}: bench must not hide worker churn");
            table.push(
                Row::new(label)
                    .cell("task_s", res.mean_s)
                    .cell("ship_bytes", c.broadcast_ship_bytes as f64)
                    .cell("ingress_bytes", c.result_ingress_bytes as f64)
                    .cell("binary_connections", c.binary_connections as f64)
                    .cell("json_connections", c.json_connections as f64),
            );
            wire.push(c);
        }
        assert_eq!(wire[0].binary_connections, 1, "stock pool must negotiate the v6 wire");
        assert_eq!(wire[0].json_connections, 0, "stock pool must not pin JSON");
        assert_eq!(wire[1].json_connections, 1, "doctored pool must pin the JSON wire");
        assert!(
            wire[0].broadcast_ship_bytes < wire[1].broadcast_ship_bytes,
            "binary broadcast ship bytes {} must undercut JSON {}",
            wire[0].broadcast_ship_bytes,
            wire[1].broadcast_ship_bytes
        );
        assert!(
            wire[0].result_ingress_bytes < wire[1].result_ingress_bytes,
            "binary result ingress {} must undercut JSON {}",
            wire[0].result_ingress_bytes,
            wire[1].result_ingress_bytes
        );
    }

    // -- replica ship accounting on a sharded workload ------------------
    let prefix = DistanceTable::auto_prefix(problem.emb.n, n / 4);
    let sharded = DistanceTable::build_truncated(&problem.emb, prefix).shard(2);
    let rows: Vec<usize> = (0..problem.emb.n).step_by(3).collect();
    for replicas in [1usize, 2] {
        let pb = spawn(TransportKind::Tcp, 2, replicas);
        let mut arena = TaskArena::new();
        for shard in sharded.shards() {
            let mut preds = Vec::new();
            pb.shard_chunk_into(shard, &problem.targets, 0.0, &rows, 2, &mut arena, &mut preds);
            assert_eq!(preds.len(), shard.num_rows());
        }
        table.push(
            Row::new(format!("tcp_replicas_{replicas}"))
                .cell("ship_bytes", pb.run_counters().broadcast_ship_bytes as f64)
                .cell("ships", pb.run_counters().broadcast_ships as f64)
                .cell("rebroadcasts", pb.run_counters().rebroadcasts as f64),
        );
    }

    // -- straggler-defense overhead on a healthy pool --------------------
    // same strict round trip as rtt_tcp, but with leases tracked and the
    // recv polled on a deadline; the counter cells document that nothing
    // straggled (a genuinely slow CI task may legitimately speculate —
    // results stay bit-identical either way, and only *_s cells gate)
    {
        let pb = ClusterBackend::with_options(
            env!("CARGO_BIN_EXE_parccm"),
            ClusterOptions {
                transport: TransportKind::Tcp,
                workers: 1,
                replicas: 1,
                task_deadline: Some(std::time::Duration::from_secs(30)),
                speculate_factor: Some(8.0),
                ..ClusterOptions::default()
            },
        )
        .expect("spawning worker processes");
        let mut arena = TaskArena::new();
        let res = bencher.run("tcp cross_map round-trip (leases on)", || {
            pb.cross_map_into(&input, &mut arena)
        });
        table.push(
            Row::new("rtt_tcp_leases")
                .cell("task_s", res.mean_s)
                .cell("vs_pipe_x", res.mean_s / pipe_s.max(1e-12))
                .cell("speculative_launches", pb.run_counters().speculative_launches as f64)
                .cell("speculative_wins", pb.run_counters().speculative_wins as f64)
                .cell("deadline_kills", pb.run_counters().deadline_kills as f64)
                .cell("corrupt_frames_detected", pb.run_counters().corrupt_frames_detected as f64)
                .cell("exhausted_fallbacks", pb.run_counters().exhausted_fallbacks as f64),
        );
    }

    // -- result ingress: driver-side vs worker-side reduce ---------------
    // one full sharded A4 case per reduce placement on a fresh 2-worker
    // TCP pool; `ingress_bytes` is the driver-side tally of accepted
    // result frames (PoolCounters::result_ingress_bytes). A single timed
    // pass per mode keeps the counter an exact per-run figure.
    {
        let mut scenario = Scenario::smoke();
        scenario.series_len = n;
        scenario.ls = vec![n / 4];
        scenario.r = 4;
        let mut measured = Vec::new();
        for (label, reduce) in [
            ("ingress_driver_reduce", ReduceMode::Driver),
            ("ingress_worker_reduce", ReduceMode::Worker),
        ] {
            let pb = Arc::new(spawn(TransportKind::Tcp, 2, 1));
            let backend: Arc<dyn ComputeBackend> = pb.clone();
            let t0 = std::time::Instant::now();
            let rep = RunSpec::new(Case::A4, &scenario, &y, &x)
                .policy(TablePolicy::TruncatedAuto)
                .shards(2)
                .reduce(reduce)
                .run(backend);
            let run_s = t0.elapsed().as_secs_f64();
            assert_eq!(rep.skills.len(), scenario.combos().len() * scenario.r);
            let bytes = pb.run_counters().result_ingress_bytes;
            assert!(bytes > 0, "{label}: accepted result frames must be counted");
            table.push(Row::new(label).cell("run_s", run_s).cell("ingress_bytes", bytes as f64));
            measured.push(bytes);
        }
        assert!(
            measured[1] < measured[0],
            "worker-side reduce must pull fewer result bytes than driver-side \
             (driver {} vs worker {})",
            measured[0],
            measured[1]
        );
    }

    table.print();
    let _ = table.save("results/BENCH_cluster.json");
    let _ = table.save("BENCH_cluster.json");
}
