//! Ablations of the design choices DESIGN.md calls out:
//!
//! 1. distance-table benefit as a function of L (the paper's "as the
//!    library size L grows ... pre-building the distance indexing table
//!    secures increasing benefit");
//! 2. asynchronous submission benefit as a function of topology width
//!    (the paper's "async cannot offer more parallelization when CPU
//!    utilization already reaches full throttle");
//! 3. partition-count sensitivity (Spark's parallelism knob);
//! 4. broadcast cost: table ship time vs per-task shipping.
//!
//! Run: `cargo bench --bench ablation [-- --full]`

mod common;

use std::sync::Arc;

use parccm::bench::report::{Row, TablePrinter};
use parccm::ccm::driver::{Case, RunSpec};
use parccm::engine::Deploy;

fn main() {
    let args = common::args();
    let base = common::scenario(&args);
    let backend = common::backend(&args);
    let (x, y) = common::workload(&base);
    let cluster = Deploy::Cluster { workers: 5, cores_per_worker: 4 };

    // 1. table benefit vs L ---------------------------------------------
    let mut t1 = TablePrinter::new("Ablation 1 — distance table benefit vs L (total task s)");
    for &l in &base.ls {
        let mut s = base.clone();
        s.ls = vec![l];
        s.es = vec![2];
        s.taus = vec![1];
        let brute = RunSpec::new(Case::A2, &s, &y, &x)
            .deploy(cluster.clone())
            .run(Arc::clone(&backend));
        let tabled = RunSpec::new(Case::A4, &s, &y, &x)
            .deploy(cluster.clone())
            .run(Arc::clone(&backend));
        t1.push(
            Row::new(format!("L={l}"))
                .cell("brute_task_s", brute.report.total_task_s)
                .cell("table_task_s", tabled.report.total_task_s)
                .cell("cut_pct", 100.0 * (1.0 - tabled.report.total_task_s / brute.report.total_task_s)),
        );
    }
    t1.print();
    let _ = t1.save("results/bench_ablation_table.json");
    let _ = t1.save("BENCH_ablation_table.json");

    // 2. async benefit vs topology width --------------------------------
    let mut t2 = TablePrinter::new("Ablation 2 — async benefit vs cluster width (sim makespan s)");
    for (w, c) in [(1usize, 2usize), (2, 2), (5, 4), (10, 4)] {
        let deploy = Deploy::Cluster { workers: w, cores_per_worker: c };
        let sync = RunSpec::new(Case::A4, &base, &y, &x)
            .deploy(deploy.clone())
            .run(Arc::clone(&backend));
        let asy = RunSpec::new(Case::A5, &base, &y, &x)
            .deploy(deploy.clone())
            .run(Arc::clone(&backend));
        t2.push(
            Row::new(format!("{w}x{c} ({} cores)", w * c))
                .cell("sync_s", sync.report.sim_makespan_s)
                .cell("async_s", asy.report.sim_makespan_s)
                .cell("gain_pct", 100.0 * (1.0 - asy.report.sim_makespan_s / sync.report.sim_makespan_s))
                .cell("util_sync", sync.report.sim_utilization)
                .cell("util_async", asy.report.sim_utilization),
        );
    }
    t2.print();
    let _ = t2.save("results/bench_ablation_async.json");
    let _ = t2.save("BENCH_ablation_async.json");

    // 3. partition-count sensitivity -------------------------------------
    let mut t3 = TablePrinter::new("Ablation 3 — partitions per job (A5, sim makespan s)");
    for parts in [2usize, 5, 10, 20, 40, 80] {
        let mut s = base.clone();
        s.partitions = parts;
        let rep = RunSpec::new(Case::A5, &s, &y, &x)
            .deploy(cluster.clone())
            .run(Arc::clone(&backend));
        t3.push(
            Row::new(format!("partitions={parts}"))
                .cell("sim_s", rep.report.sim_makespan_s)
                .cell("util", rep.report.sim_utilization)
                .cell("measured_s", rep.report.measured_wall_s),
        );
    }
    t3.print();
    let _ = t3.save("results/bench_ablation_partitions.json");
    let _ = t3.save("BENCH_ablation_partitions.json");

    // 4. broadcast ship accounting ---------------------------------------
    let mut t4 = TablePrinter::new("Ablation 4 — broadcast ship share (A5, 5x4)");
    let rep = RunSpec::new(Case::A5, &base, &y, &x).deploy(cluster).run(Arc::clone(&backend));
    t4.push(
        Row::new("baseline grid")
            .cell("sim_makespan_s", rep.report.sim_makespan_s)
            .cell("ship_s_total", rep.report.sim_broadcast_ship_s)
            .cell("ship_pct_of_makespan", 100.0 * rep.report.sim_broadcast_ship_s
                / (rep.report.sim_makespan_s * 5.0).max(1e-12)),
    );
    t4.print();
    let _ = t4.save("results/bench_ablation_broadcast.json");
    let _ = t4.save("BENCH_ablation_broadcast.json");
}
