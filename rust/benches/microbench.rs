//! Primitive-level microbenchmarks — the §Perf iteration loop measures
//! these before/after each hot-path change (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench microbench [-- --backend xla]`

mod common;

use parccm::bench::report::{Row, TablePrinter};
use parccm::bench::Bencher;
use parccm::ccm::backend::{ComputeBackend, TaskArena};
use parccm::ccm::embedding::Embedding;
use parccm::ccm::knn::knn_batch_into;
use parccm::ccm::params::CcmParams;
use parccm::ccm::pipeline::CcmProblem;
use parccm::ccm::subsample::draw_samples;
use parccm::ccm::table::{DistanceTable, LibraryMask};
use parccm::native::NativeBackend;
use parccm::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
use parccm::util::rng::Rng;

fn main() {
    let args = common::args();
    let n_series = args.get_usize("n", common::default_n(&args, 1000, 256));
    let (x, y) = coupled_logistic(n_series, CoupledLogisticParams::default());
    let emb = Embedding::new(&y, 2, 1);
    let targets = emb.align_targets(&x);
    let bencher = Bencher::new().warmup(1).samples(args.get_usize("repeats", 5));

    let mut table = TablePrinter::new(format!("microbench (manifold n={})", emb.n));

    // library of 1/4 the manifold
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let sample =
        &draw_samples(&Rng::new(1), CcmParams::new(2, 1, emb.n / 4), emb.n, 1)[0];
    let input = problem.input_for(sample);
    let mut arena = TaskArena::new();
    arena.gather_library(&input);

    let r = bencher.run("knn_batch (brute k-NN, full manifold queries)", || {
        knn_batch_into(
            input.vecs,
            input.times,
            &arena.lib_vecs,
            &arena.lib_targets,
            &arena.lib_times,
            0.0,
            &mut arena.dist,
            &mut arena.dvals,
            &mut arena.tvals,
        )
    });
    table.push(Row::new("knn_batch").cell("mean_s", r.mean_s).cell("std_s", r.std_s));

    let mut cm_arena = TaskArena::new();
    let r = bencher.run("native cross_map (one subsample, arena-reused)", || {
        NativeBackend.cross_map_into(&input, &mut cm_arena)
    });
    table.push(Row::new("native_cross_map").cell("mean_s", r.mean_s).cell("std_s", r.std_s));

    let r = bencher.run("distance table build (serial, full)", || DistanceTable::build(&emb));
    table.push(Row::new("table_build").cell("mean_s", r.mean_s).cell("std_s", r.std_s));

    let prefix = DistanceTable::auto_prefix(emb.n, emb.n / 4);
    let r = bencher.run("distance table build (serial, truncated)", || {
        DistanceTable::build_truncated(&emb, prefix)
    });
    table.push(Row::new("table_build_truncated").cell("mean_s", r.mean_s).cell("std_s", r.std_s));

    let dt = DistanceTable::build(&emb);
    let dt_trunc = DistanceTable::build_truncated(&emb, prefix);
    let mut mask = LibraryMask::new();
    mask.set_from(emb.n, &sample.rows);
    let mut qa = TaskArena::new();
    let r = bencher.run("table query_all (one subsample, full)", || {
        dt.query_all_into(&sample.rows, &mask, &targets, 0.0, &mut qa.dvals, &mut qa.tvals)
    });
    table.push(Row::new("table_query_all").cell("mean_s", r.mean_s).cell("std_s", r.std_s));
    let r = bencher.run("table query_all (one subsample, truncated)", || {
        dt_trunc.query_all_into(&sample.rows, &mask, &targets, 0.0, &mut qa.dvals, &mut qa.tvals)
    });
    table.push(
        Row::new("table_query_all_truncated").cell("mean_s", r.mean_s).cell("std_s", r.std_s),
    );

    // XLA path, when available
    let backend = common::backend(&args);
    if backend.name() == "xla" {
        let r = bencher.run("xla cross_map (one subsample, incl. padding)", || {
            backend.cross_map(&input)
        });
        table.push(Row::new("xla_cross_map").cell("mean_s", r.mean_s).cell("std_s", r.std_s));
        let r = bencher.run("xla distance_matrix (manifold)", || {
            backend.distance_matrix(&emb.vecs, emb.n)
        });
        table.push(Row::new("xla_distance_matrix").cell("mean_s", r.mean_s).cell("std_s", r.std_s));
    }

    table.print();
    let _ = table.save("results/bench_micro.json");
    let _ = table.save("BENCH_micro.json");
}
