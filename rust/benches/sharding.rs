//! Sharded distance-table bench (ISSUE 2 acceptance support): what
//! splitting the broadcast into per-node row-range shards costs and buys.
//!
//! * broadcast footprint: monolithic table bytes vs the per-shard sum and
//!   the largest single shard (what one node must hold);
//! * DES ship accounting on the paper's 5x4 cluster: bytes and seconds
//!   actually shipped per shard count (per-shard jobs let nodes skip
//!   shards they never query);
//! * query cost: sharded facade vs monolithic table walk over the same
//!   libraries (should be within noise — same walk code).
//!
//! Run: `cargo bench --bench sharding [-- --tiny | --full]`
//! Emits `BENCH_sharding.json` (and `results/BENCH_sharding.json`).

mod common;

use std::sync::Arc;

use parccm::bench::report::{Row, TablePrinter};
use parccm::bench::Bencher;
use parccm::ccm::driver::{Case, RunSpec, TablePolicy};
use parccm::ccm::pipeline::CcmProblem;
use parccm::ccm::table::{DistanceTable, LibraryMask};
use parccm::engine::Deploy;
use parccm::util::rng::Rng;

fn main() {
    let args = common::args();
    let scenario = common::scenario(&args);
    let backend = common::backend(&args);
    let (x, y) = common::workload(&scenario);
    let bencher = Bencher::new().warmup(1).samples(common::repeats(&args, 3));
    let mut table = TablePrinter::new(format!(
        "sharding (series={}, r={}, L={:?})",
        scenario.series_len, scenario.r, scenario.ls
    ));

    // -- broadcast footprint + query cost, driver-side ------------------
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let n = problem.emb.n;
    let min_l = scenario.ls.iter().copied().min().unwrap_or(1);
    let prefix = DistanceTable::auto_prefix(n, min_l);
    let mono = DistanceTable::build_truncated(&problem.emb, prefix);
    let mut rng = Rng::new(17);
    let rows = rng.sample_indices(n, min_l.min(n));
    let mut mask = LibraryMask::new();
    mask.set_from(n, &rows);
    let mono_q = bencher.run("monolithic query_all (one sample)", || {
        mono.query_all(&rows, &mask, &problem.targets, 0.0)
    });

    for shards in [1usize, 2, 4, 8] {
        let sharded = mono.shard(shards);
        let max_shard =
            sharded.shards().iter().map(|s| s.size_bytes()).max().unwrap_or(0);
        let shard_q = bencher.run(&format!("sharded({shards}) query_all"), || {
            sharded.query_all(&rows, &mask, &problem.targets, 0.0)
        });
        table.push(
            Row::new(format!("layout_shards_{shards}"))
                .cell("mono_bytes", mono.size_bytes() as f64)
                .cell("total_bytes", sharded.size_bytes() as f64)
                .cell("max_node_bytes", max_shard as f64)
                .cell("node_cut_x", mono.size_bytes() as f64 / max_shard.max(1) as f64)
                .cell("query_s", shard_q.mean_s)
                .cell("query_vs_mono_x", shard_q.mean_s / mono_q.mean_s.max(1e-12)),
        );
    }

    // -- DES ship accounting through the full A4 driver -----------------
    for shards in [1usize, 2, 4, 8] {
        let rep = RunSpec::new(Case::A4, &scenario, &y, &x)
            .deploy(Deploy::paper_cluster())
            .policy(TablePolicy::TruncatedAuto)
            .shards(shards)
            .run(Arc::clone(&backend));
        table.push(
            Row::new(format!("des_shards_{shards}"))
                .cell("sim_makespan_s", rep.report.sim_makespan_s)
                .cell("ship_s", rep.report.sim_broadcast_ship_s)
                .cell("ship_bytes", rep.report.sim_broadcast_ship_bytes as f64)
                .cell("util", rep.report.sim_utilization),
        );
    }

    table.print();
    let _ = table.save("results/BENCH_sharding.json");
    let _ = table.save("BENCH_sharding.json");
}
