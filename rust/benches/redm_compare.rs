//! §4.1 external-comparator bench: the fully-parallel A5 pipeline vs the
//! rEDM-style sequential implementation, across problem scales.
//!
//! Paper claim: "our Spark parallel implementation (Case A5) is
//! approximately 15x faster than rEDM for the baseline scenario on the
//! current cluster setup" (5 workers x 4 cores). The DES supplies the
//! cluster topology; the ratio should sit near the topology's core count
//! times the table-pipeline algorithmic gain.
//!
//! Run: `cargo bench --bench redm_compare [-- --full]`

mod common;

use std::sync::Arc;

use parccm::baseline::{redm_ccm, RedmConfig};
use parccm::bench::report::{Row, TablePrinter};
use parccm::bench::Bencher;
use parccm::ccm::driver::{Case, RunSpec};
use parccm::engine::Deploy;

fn main() {
    let args = common::args();
    let base = common::scenario(&args);
    let backend = common::backend(&args);
    let repeats = common::repeats(&args, 3);
    let cluster = Deploy::Cluster {
        workers: args.get_usize("workers", 5),
        cores_per_worker: args.get_usize("cores", 4),
    };
    let (x, y) = common::workload(&base);

    let mut table = TablePrinter::new("rEDM-style sequential vs A5 (per-combo grid)");
    for &l in &base.ls {
        let mut s = base.clone();
        s.ls = vec![l];
        // rEDM side: sequential loop over the same (E, tau) grid
        let redm = Bencher::new().quiet(true).warmup(0).samples(repeats).run("redm", || {
            let mut n = 0usize;
            for combo in s.combos() {
                n += redm_ccm(
                    &y,
                    &x,
                    &RedmConfig {
                        params: combo,
                        r: s.r,
                        theiler: s.theiler as f32,
                        seed: s.seed,
                    },
                )
                .len();
            }
            n
        });
        let a5 = Bencher::new().quiet(true).warmup(0).samples(repeats).run("a5", || {
            RunSpec::new(Case::A5, &s, &y, &x)
                .deploy(cluster.clone())
                .run(Arc::clone(&backend))
                .report
                .sim_makespan_s
        });
        // a5 sample values are the DES makespans, not the wall time of the
        // bench closure: recompute the mean from a fresh run set
        let mut sim = Vec::new();
        for _ in 0..repeats {
            sim.push(
                RunSpec::new(Case::A5, &s, &y, &x)
                    .deploy(cluster.clone())
                    .run(Arc::clone(&backend))
                    .report
                    .sim_makespan_s,
            );
        }
        let sim_mean = parccm::util::stats::mean(&sim);
        let _ = a5;
        table.push(
            Row::new(format!("L={l} (grid ExT={}x{})", s.es.len(), s.taus.len()))
                .cell("redm_s", redm.mean_s)
                .cell("a5_sim_s", sim_mean)
                .cell("speedup", redm.mean_s / sim_mean.max(1e-12)),
        );
    }
    table.print();
    let _ = table.save("results/bench_redm.json");
    let _ = table.save("BENCH_redm.json");
    println!("\n(paper: ~15x at the baseline scenario on the 5x4 cluster)");
}
