//! END-TO-END DRIVER (the validation run recorded in EXPERIMENTS.md).
//!
//! Exercises every layer on a real workload: generates the paper's
//! baseline-shaped coupled-logistic series, runs ALL FIVE implementation
//! levels (Table 1) through the engine — RDD pipelines, distance indexing
//! table broadcast, asynchronous job futures — on the XLA backend when
//! `artifacts/` exists (AOT Pallas kernels via PJRT) and the native
//! backend otherwise, verifies all cases agree numerically, prints the
//! Fig. 4-shaped timing table and the scientific conclusion.
//!
//! ```sh
//! cargo run --release --example param_sweep            # scaled scenario
//! cargo run --release --example param_sweep -- --full  # paper scale
//! cargo run --release --example param_sweep -- --quick # CI smoke
//! ```

use std::sync::Arc;

use parccm::bench::report::{Row, TablePrinter};
use parccm::ccm::backend::ComputeBackend;
use parccm::ccm::convergence::assess;
use parccm::ccm::driver::{Case, RunSpec};
use parccm::ccm::params::Scenario;
use parccm::ccm::result::summarize;
use parccm::engine::Deploy;
use parccm::native::NativeBackend;
use parccm::runtime::{artifacts_available, XlaBackend, DEFAULT_ARTIFACTS_DIR};
use parccm::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
use parccm::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let mut scenario = if args.flag("full") {
        Scenario::paper_baseline()
    } else if args.flag("quick") {
        Scenario::smoke()
    } else {
        Scenario::scaled_baseline()
    };
    scenario.seed = args.get_u64("seed", scenario.seed);

    let backend: Arc<dyn ComputeBackend> = if artifacts_available(DEFAULT_ARTIFACTS_DIR)
        && !args.flag("native")
    {
        match XlaBackend::from_dir(DEFAULT_ARTIFACTS_DIR, args.get_usize("xla-pool", 1)) {
            Ok(b) => {
                println!("backend: XLA (AOT Pallas kernels via PJRT)");
                Arc::new(b)
            }
            Err(e) => {
                println!("backend: native (xla failed to start: {e:#})");
                Arc::new(NativeBackend)
            }
        }
    } else {
        println!("backend: native (run `make artifacts` to enable XLA)");
        Arc::new(NativeBackend)
    };

    let (x, y) = coupled_logistic(scenario.series_len, CoupledLogisticParams::default());
    println!(
        "scenario: series={} r={} L={:?} E={:?} tau={:?} ({} combos x {} realizations)\n",
        scenario.series_len,
        scenario.r,
        scenario.ls,
        scenario.es,
        scenario.taus,
        scenario.combos().len(),
        scenario.r
    );

    let cluster = Deploy::paper_cluster();
    let mut table = TablePrinter::new("End-to-end: all implementation levels (X -> Y)");
    let mut canonical: Option<Vec<(usize, usize, usize, usize, f32)>> = None;
    let mut a1_time = f64::NAN;
    let mut a5_skills = Vec::new();
    for case in Case::ALL {
        let rep = RunSpec::new(case, &scenario, &y, &x)
            .deploy(cluster.clone())
            .run(Arc::clone(&backend));
        // cross-case numeric equivalence (the Table-1 levels are
        // scheduling variants of the same computation)
        let mut keyed: Vec<(usize, usize, usize, usize, f32)> = rep
            .skills
            .iter()
            .map(|r| (r.params.e, r.params.tau, r.params.l, r.sample_id, r.rho))
            .collect();
        keyed.sort_by(|a, b| (a.0, a.1, a.2, a.3).cmp(&(b.0, b.1, b.2, b.3)));
        match &canonical {
            None => canonical = Some(keyed),
            Some(want) => {
                assert_eq!(want.len(), keyed.len(), "{case:?} row count");
                for (a, b) in want.iter().zip(&keyed) {
                    assert!(
                        (a.4 - b.4).abs() < 1e-4,
                        "{case:?} diverges from A1 at {:?}: {} vs {}",
                        (a.0, a.1, a.2, a.3),
                        b.4,
                        a.4
                    );
                }
            }
        }
        if case == Case::A1 {
            a1_time = rep.report.sim_makespan_s;
        }
        if case == Case::A5 {
            a5_skills = rep.skills.clone();
        }
        table.push(
            Row::new(format!("{} {}", case.name(), case.description()))
                .cell("sim_yarn_s", rep.report.sim_makespan_s)
                .cell("measured_s", rep.report.measured_wall_s)
                .cell("task_s", rep.report.total_task_s)
                .cell("vs_A1", rep.report.sim_makespan_s / a1_time),
        );
    }
    table.print();
    let _ = table.save("results/param_sweep.json");
    println!("\nall five cases agree numerically ✓");

    // scientific readout per (E, tau): convergence across the L sweep
    println!("\nconvergence verdicts (X -> Y should be causal):");
    let summaries = summarize(&a5_skills);
    for &e in &scenario.es {
        for &tau in &scenario.taus {
            let cell: Vec<_> = summaries
                .iter()
                .filter(|s| s.params.e == e && s.params.tau == tau)
                .cloned()
                .collect();
            if cell.is_empty() {
                continue;
            }
            let v = assess(&cell, 0.1, 0.01);
            println!(
                "  E={e} tau={tau}: rho {:.3} -> {:.3} (delta {:+.3}) {}",
                v.rho_min_l,
                v.rho_max_l,
                v.delta,
                if v.causal { "CAUSAL" } else { "-" }
            );
        }
    }
    println!("\ndone — results/param_sweep.json written; see EXPERIMENTS.md");
}
