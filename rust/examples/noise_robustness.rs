//! Noise-robustness sweep (Mønster et al. 2017 studied CCM under noise —
//! the paper cites it as the motivation for needing many subsamples r).
//!
//! ```sh
//! cargo run --release --example noise_robustness
//! ```
//!
//! Adds increasing observation noise to the coupled-logistic pair and
//! tracks how the convergent cross-map signal degrades, using the full
//! A5 pipeline per noise level.

use std::sync::Arc;

use parccm::bench::report::{Row, TablePrinter};
use parccm::ccm::convergence::assess;
use parccm::ccm::driver::{Case, RunSpec};
use parccm::ccm::params::Scenario;
use parccm::ccm::result::summarize;
use parccm::engine::Deploy;
use parccm::native::NativeBackend;
use parccm::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
use parccm::timeseries::noise::add_gaussian;

fn main() {
    let (x0, y0) = coupled_logistic(900, CoupledLogisticParams::default());
    let scenario = Scenario {
        series_len: 900,
        r: 16,
        ls: vec![80, 300, 700],
        es: vec![2],
        taus: vec![1],
        theiler: 0,
        seed: 77,
        partitions: 8,
    };
    let backend = Arc::new(NativeBackend);

    let mut table = TablePrinter::new("CCM signal vs observation noise (X -> Y)");
    for (i, sigma) in [0.0, 0.05, 0.1, 0.2, 0.4, 0.8].iter().enumerate() {
        let x = add_gaussian(&x0, *sigma, 100 + i as u64);
        let y = add_gaussian(&y0, *sigma, 200 + i as u64);
        let rep = RunSpec::new(Case::A5, &scenario, &y, &x)
            .deploy(Deploy::paper_cluster())
            .run(backend.clone());
        let summaries = summarize(&rep.skills);
        let v = assess(&summaries, 0.1, 0.02);
        table.push(
            Row::new(format!("sigma={sigma}"))
                .cell("rho_Lmin", v.rho_min_l)
                .cell("rho_Lmax", v.rho_max_l)
                .cell("delta", v.delta)
                .cell("causal", if v.causal { 1.0 } else { 0.0 }),
        );
    }
    table.print();
    let _ = table.save("results/noise_robustness.json");
    println!("\n(skill and convergence degrade smoothly with noise; the causal\n verdict should survive moderate noise and die at extreme noise)");
}
