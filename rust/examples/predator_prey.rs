//! Predator–prey example on the real Hudson Bay hare/lynx record — the
//! motivating system of the paper's introduction ("X measures the count
//! of hares, and Y that of lynx").
//!
//! ```sh
//! cargo run --release --example predator_prey
//! ```
//!
//! The raw record is 21 yearly points — far below CCM's n ~ 10^3 needs
//! (Ma et al. 2014), so the example linearly upsamples it to a dense
//! series: a demonstration of running the full stack on real-shaped data,
//! not an ecological claim (see DESIGN.md).

use std::sync::Arc;

use parccm::ccm::convergence::assess;
use parccm::ccm::driver::{Case, RunSpec};
use parccm::ccm::params::Scenario;
use parccm::ccm::result::summarize;
use parccm::ccm::surrogate::{significance_test, SurrogateKind};
use parccm::ccm::params::CcmParams;
use parccm::engine::Deploy;
use parccm::native::NativeBackend;
use parccm::timeseries::data::{upsample_linear, HARES, LYNX, YEARS};

fn main() {
    println!(
        "Hudson Bay pelt record, {}-{} (thousands):",
        YEARS[0],
        YEARS[YEARS.len() - 1]
    );
    for (i, year) in YEARS.iter().enumerate().step_by(4) {
        println!("  {year}: hares {:>5.1}, lynx {:>5.1}", HARES[i], LYNX[i]);
    }

    let k = 40; // upsampling factor -> 801 points
    let hares = upsample_linear(&HARES, k);
    let lynx = upsample_linear(&LYNX, k);
    println!("\nupsampled x{k} -> {} points (demonstration only)\n", hares.len());

    let scenario = Scenario {
        series_len: hares.len(),
        r: 20,
        ls: vec![100, 250, 500, 750],
        es: vec![3],
        taus: vec![8],
        theiler: 10, // exclude temporal neighbours: upsampling is smooth
        seed: 1900,
        partitions: 8,
    };
    let backend = Arc::new(NativeBackend);

    for (effect, cause, label) in
        [(&lynx, &hares, "hares -> lynx"), (&hares, &lynx, "lynx -> hares")]
    {
        let rep = RunSpec::new(Case::A5, &scenario, effect, cause)
            .deploy(Deploy::paper_cluster())
            .run(backend.clone());
        let summaries = summarize(&rep.skills);
        println!("direction {label}:");
        for s in &summaries {
            println!("  L={:<5} rho={:+.4} ± {:.4}", s.params.l, s.mean_rho, s.std_rho);
        }
        let v = assess(&summaries, 0.15, 0.02);
        println!(
            "  convergence delta={:+.4} => {}\n",
            v.delta,
            if v.causal { "CAUSAL signal" } else { "no convergent signal" }
        );
    }

    // significance against circular-shift surrogates
    let sig = significance_test(
        &lynx,
        &hares,
        CcmParams::new(3, 8, 500),
        8,
        10.0,
        SurrogateKind::CircularShift,
        19,
        7,
        backend,
    );
    println!(
        "surrogate test (hares -> lynx): observed rho {:.3}, p = {:.3} ({})",
        sig.observed_rho,
        sig.p_value,
        if sig.p_value <= 0.05 { "significant" } else { "not significant" }
    );
}
