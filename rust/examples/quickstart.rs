//! Quickstart: detect causality in a coupled system in ~20 lines.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Generates Sugihara's coupled logistic maps (X drives Y), runs the fully
//! parallel CCM (Case A5: distance indexing table + asynchronous
//! pipelines) across a library-size sweep, and prints the convergence
//! diagnostics for both directions.

use std::sync::Arc;

use parccm::ccm::convergence::assess;
use parccm::ccm::driver::{Case, RunSpec};
use parccm::ccm::params::Scenario;
use parccm::ccm::result::summarize;
use parccm::engine::Deploy;
use parccm::native::NativeBackend;
use parccm::timeseries::generators::{coupled_logistic, CoupledLogisticParams};

fn main() {
    // X -> Y coupling is strong (byx = 0.1), Y -> X is weak (bxy = 0.02).
    let (x, y) = coupled_logistic(1000, CoupledLogisticParams::default());

    let scenario = Scenario {
        series_len: 1000,
        r: 25,
        ls: vec![100, 200, 400, 800],
        es: vec![2],
        taus: vec![1],
        theiler: 0,
        seed: 42,
        partitions: 8,
    };
    let backend = Arc::new(NativeBackend);

    println!("CCM on coupled logistic maps (n = 1000, r = 25)\n");
    for (effect, cause, label) in [(&y, &x, "X -> Y"), (&x, &y, "Y -> X")] {
        let rep = RunSpec::new(Case::A5, &scenario, effect, cause)
            .deploy(Deploy::paper_cluster())
            .run(backend.clone());
        let summaries = summarize(&rep.skills);
        println!("direction {label}:   (cross-map skill rho vs library size L)");
        for s in &summaries {
            let bar = "#".repeat((s.mean_rho.max(0.0) * 40.0) as usize);
            println!("  L={:<5} rho={:+.4} ± {:.4}  {bar}", s.params.l, s.mean_rho, s.std_rho);
        }
        let v = assess(&summaries, 0.1, 0.02);
        println!(
            "  convergence: delta={:+.4} increasing={} => {}\n",
            v.delta,
            v.increasing,
            if v.causal { "CAUSAL" } else { "not causal" }
        );
    }
    println!("(strong convergent skill for X -> Y, weaker for Y -> X — Sugihara et al. 2012)");
}
