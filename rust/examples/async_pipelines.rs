//! Asynchronous pipelines close-up (paper §3.3 / Fig. 3).
//!
//! ```sh
//! cargo run --release --example async_pipelines
//! ```
//!
//! Runs the same multi-combination workload with synchronous and
//! asynchronous job submission on progressively wider (simulated)
//! clusters, showing the paper's observation: async helps only while the
//! topology has idle cores ("the asynchronous pipelines cannot offer more
//! parallelization when the CPU utilization already reaches full
//! throttle").

use std::sync::Arc;

use parccm::bench::report::{Row, TablePrinter};
use parccm::ccm::driver::{Case, RunSpec};
use parccm::ccm::params::Scenario;
use parccm::engine::Deploy;
use parccm::native::NativeBackend;
use parccm::timeseries::generators::{coupled_logistic, CoupledLogisticParams};

fn main() {
    let scenario = Scenario {
        series_len: 700,
        r: 24,
        ls: vec![80, 160, 320],
        es: vec![1, 2, 4],
        taus: vec![1],
        theiler: 0,
        seed: 31,
        partitions: 6,
    };
    let (x, y) = coupled_logistic(scenario.series_len, CoupledLogisticParams::default());
    let backend = Arc::new(NativeBackend);

    println!(
        "workload: {} jobs of {} tasks each (9 combos x {} partitions)\n",
        scenario.combos().len(),
        scenario.partitions,
        scenario.partitions
    );

    let mut table = TablePrinter::new("sync (A4) vs async (A5) across topologies");
    for (w, c) in [(1usize, 1usize), (1, 4), (2, 4), (5, 4), (10, 4), (20, 4)] {
        let deploy = Deploy::Cluster { workers: w, cores_per_worker: c };
        let sync = RunSpec::new(Case::A4, &scenario, &y, &x)
            .deploy(deploy.clone())
            .run(backend.clone());
        let asy = RunSpec::new(Case::A5, &scenario, &y, &x).deploy(deploy).run(backend.clone());
        let gain = 100.0 * (1.0 - asy.report.sim_makespan_s / sync.report.sim_makespan_s);
        table.push(
            Row::new(format!("{w} workers x {c} cores"))
                .cell("sync_s", sync.report.sim_makespan_s)
                .cell("async_s", asy.report.sim_makespan_s)
                .cell("async_gain_pct", gain)
                .cell("sync_util", sync.report.sim_utilization)
                .cell("async_util", asy.report.sim_utilization),
        );
    }
    table.print();
    let _ = table.save("results/async_pipelines.json");
    println!("\n(gain should grow with idle width, saturating utilization where narrow)");
}
