//! Full scientific workflow on a continuous-time system (Lorenz-63):
//! parameter selection -> forecast validation -> CCM causality.
//!
//! ```sh
//! cargo run --release --example lorenz_workflow
//! ```
//!
//! Demonstrates the library's non-CCM machinery the way a practitioner
//! would use it: pick tau by average mutual information, pick E by Cao's
//! method and by forecast skill, confirm determinism with an S-map theta
//! sweep, then run CCM between two Lorenz coordinates (bidirectionally
//! coupled within one attractor — both directions should cross-map).

use std::sync::Arc;

use parccm::ccm::convergence::assess;
use parccm::ccm::driver::{Case, RunSpec};
use parccm::ccm::forecast::{simplex_forecast, smap_forecast};
use parccm::ccm::params::Scenario;
use parccm::ccm::result::summarize;
use parccm::ccm::select::{cao_e1, mutual_information, select_e_cao, select_e_forecast, select_tau_ami};
use parccm::engine::Deploy;
use parccm::native::NativeBackend;
use parccm::timeseries::generators::lorenz63;

fn main() {
    let (x, _y, z) = lorenz63(2000, 0.01, 3);
    println!("Lorenz-63, 2000 samples at dt=0.03\n");

    // 1. tau by AMI
    let ami = mutual_information(&x, 30, 16);
    let tau = select_tau_ami(&x, 30, 16);
    println!("1. embedding delay: first AMI minimum at tau = {tau}");
    println!("   AMI[1..10] = {:?}", ami[..10].iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>());

    // 2. E by Cao and by forecast skill
    let e_cao = select_e_cao(&x, tau, 6, 0.12);
    let e1 = cao_e1(&x, tau, 6);
    let (e_fc, skills) = select_e_forecast(&x, tau, 6);
    println!("\n2. embedding dimension:");
    println!("   Cao E1 = {:?} -> E = {e_cao}", e1.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>());
    println!("   forecast rho(E) = {:?} -> E = {e_fc}", skills.iter().map(|v| (v * 1000.0).round() / 1000.0).collect::<Vec<_>>());
    let e = e_cao.clamp(2, 4);

    // 3. determinism check: simplex horizon decay + S-map theta sweep
    println!("\n3. dynamics checks (E={e}, tau={tau}):");
    for tp in [1usize, 5, 10] {
        let r = simplex_forecast(&x, e, tau, tp);
        println!("   simplex tp={tp}: rho={:.4}", r.rho);
    }
    let lin = smap_forecast(&x, e, tau, 1, 0.0).rho;
    let nl = smap_forecast(&x, e, tau, 1, 2.0).rho;
    println!("   S-map theta=0: {lin:.4}  theta=2: {nl:.4}  (nonlinear if theta>0 wins)");

    // 4. CCM between x and z (same attractor: expect bidirectional)
    println!("\n4. CCM x <-> z:");
    let scenario = Scenario {
        series_len: x.len(),
        r: 15,
        ls: vec![150, 400, 1000, 1800],
        es: vec![e],
        taus: vec![tau],
        theiler: 10,
        seed: 63,
        partitions: 8,
    };
    let backend = Arc::new(NativeBackend);
    for (effect, cause, label) in [(&z, &x, "x -> z"), (&x, &z, "z -> x")] {
        let rep = RunSpec::new(Case::A5, &scenario, effect, cause)
            .deploy(Deploy::paper_cluster())
            .run(backend.clone());
        let summaries = summarize(&rep.skills);
        let v = assess(&summaries, 0.2, 0.02);
        print!("   {label}: ");
        for s in &summaries {
            print!("L={} rho={:.3}  ", s.params.l, s.mean_rho);
        }
        println!("=> {}", if v.causal { "CAUSAL" } else { "not causal" });
    }
    println!("\n(coordinates of one attractor cross-map in both directions — Sugihara 2012)");
}
