//! CCM scientific integration: the algorithm recovers the causal
//! structure of known systems through the full engine+pipeline stack, and
//! all five implementation levels agree.

use std::sync::Arc;

use parccm::ccm::backend::ComputeBackend;
use parccm::ccm::convergence::assess;
use parccm::ccm::driver::{Case, RunSpec};
use parccm::ccm::params::Scenario;
use parccm::ccm::result::summarize;
use parccm::engine::Deploy;
use parccm::native::NativeBackend;
use parccm::timeseries::generators::{ar1, coupled_logistic, CoupledLogisticParams};

fn backend() -> Arc<dyn ComputeBackend> {
    Arc::new(NativeBackend)
}

fn scenario(n: usize, r: usize, ls: Vec<usize>) -> Scenario {
    Scenario {
        series_len: n,
        r,
        ls,
        es: vec![2],
        taus: vec![1],
        theiler: 0,
        seed: 99,
        partitions: 4,
    }
}

#[test]
fn detects_unidirectional_coupling_direction() {
    // X drives Y strongly (byx) and Y barely drives X (bxy ~ 0):
    // cross-mapping X from M_Y must converge high; the reverse must stay low.
    let (x, y) = coupled_logistic(
        800,
        CoupledLogisticParams { bxy: 0.0, byx: 0.32, ..Default::default() },
    );
    let s = scenario(800, 12, vec![50, 200, 600]);
    let xy = RunSpec::new(Case::A4, &s, &y, &x).deploy(Deploy::Local { cores: 2 }).run(backend());
    let yx = RunSpec::new(Case::A4, &s, &x, &y).deploy(Deploy::Local { cores: 2 }).run(backend());
    let sum_xy = summarize(&xy.skills);
    let sum_yx = summarize(&yx.skills);
    let v_xy = assess(&sum_xy, 0.1, 0.03);
    assert!(v_xy.causal, "X->Y should be causal: {:?}", sum_xy.iter().map(|s| s.mean_rho).collect::<Vec<_>>());
    let top_xy = sum_xy.iter().map(|s| s.mean_rho).fold(0.0, f64::max);
    let top_yx = sum_yx.iter().map(|s| s.mean_rho).fold(0.0, f64::max);
    assert!(
        top_xy > top_yx + 0.15,
        "asymmetry lost: X->Y {top_xy:.3} vs Y->X {top_yx:.3}"
    );
}

#[test]
fn bidirectional_coupling_detected_both_ways() {
    let (x, y) = coupled_logistic(
        700,
        CoupledLogisticParams { bxy: 0.1, byx: 0.1, ..Default::default() },
    );
    let s = scenario(700, 10, vec![60, 500]);
    for (effect, cause, dir) in [(&y, &x, "X->Y"), (&x, &y, "Y->X")] {
        let rep = RunSpec::new(Case::A4, &s, effect, cause)
            .deploy(Deploy::Local { cores: 2 })
            .run(backend());
        let summaries = summarize(&rep.skills);
        let v = assess(&summaries, 0.1, 0.02);
        assert!(v.causal, "{dir} should be causal: {summaries:?}");
    }
}

#[test]
fn no_false_positive_on_independent_series() {
    let a = ar1(700, 0.6, 1);
    let b = ar1(700, 0.6, 2);
    let s = scenario(700, 10, vec![60, 500]);
    let rep = RunSpec::new(Case::A4, &s, &b, &a).deploy(Deploy::Local { cores: 2 }).run(backend());
    let summaries = summarize(&rep.skills);
    let top = summaries.iter().map(|x| x.mean_rho).fold(f64::MIN, f64::max);
    assert!(top < 0.35, "independent AR(1) pair shows skill {top}");
}

#[test]
fn convergence_with_library_size() {
    let (x, y) = coupled_logistic(900, CoupledLogisticParams::default());
    let s = scenario(900, 15, vec![40, 100, 300, 800]);
    let rep = RunSpec::new(Case::A5, &s, &y, &x).deploy(Deploy::paper_cluster()).run(backend());
    let summaries = summarize(&rep.skills);
    assert_eq!(summaries.len(), 4);
    // monotone non-decreasing in L (tolerance folded into assess)
    let v = assess(&summaries, 0.2, 0.05);
    assert!(v.causal, "{summaries:?}");
    assert!(v.rho_max_l > 0.85, "strong coupling should cross-map well: {v:?}");
}

#[test]
fn skills_identical_across_cases_large() {
    // bigger replica of the driver unit test: A1 == A2..A5 numerically.
    let (x, y) = coupled_logistic(500, CoupledLogisticParams::default());
    let s = scenario(500, 6, vec![80, 250]);
    let canon = {
        let mut rows = RunSpec::new(Case::A1, &s, &y, &x).run(backend()).skills;
        rows.sort_by_key(|r| (r.params.l, r.sample_id));
        rows
    };
    for case in [Case::A2, Case::A3, Case::A4, Case::A5] {
        let mut rows =
            RunSpec::new(case, &s, &y, &x).deploy(Deploy::paper_cluster()).run(backend()).skills;
        rows.sort_by_key(|r| (r.params.l, r.sample_id));
        assert_eq!(rows.len(), canon.len());
        for (a, b) in canon.iter().zip(&rows) {
            assert!(
                (a.rho - b.rho).abs() < 1e-5,
                "{case:?} diverges at L={} sample={}",
                a.params.l,
                a.sample_id
            );
        }
    }
}

#[test]
fn theiler_window_reduces_skill_of_autocorrelated_match() {
    // With a wide Theiler window the nearest temporal neighbours are
    // excluded; skill should drop (slightly) but stay defined.
    let (x, y) = coupled_logistic(600, CoupledLogisticParams::default());
    let mut s = scenario(600, 8, vec![300]);
    let base = RunSpec::new(Case::A4, &s, &y, &x).deploy(Deploy::Local { cores: 2 }).run(backend());
    s.theiler = 20;
    let windowed =
        RunSpec::new(Case::A4, &s, &y, &x).deploy(Deploy::Local { cores: 2 }).run(backend());
    let rho_base = summarize(&base.skills)[0].mean_rho;
    let rho_win = summarize(&windowed.skills)[0].mean_rho;
    assert!(rho_win.is_finite());
    assert!(rho_win <= rho_base + 0.05, "theiler window should not inflate skill");
}
