//! Engine integration: end-to-end jobs through context + scheduler +
//! executors + DES, including the paper-relevant scheduling semantics.

use parccm::engine::{Context, Deploy, EngineConfig, Pipeline};

fn ctx(deploy: Deploy, partitions: usize) -> Context {
    Context::new(EngineConfig::new(deploy).with_default_parallelism(partitions))
}

#[test]
fn large_job_roundtrip() {
    let c = ctx(Deploy::Local { cores: 4 }, 16);
    let rdd = c
        .parallelize((0..100_000i64).collect())
        .map(|x| x * 2)
        .filter(|x| x % 3 == 0)
        .map(|x| x / 2);
    let got = c.collect(&rdd);
    let want: Vec<i64> = (0..100_000).map(|x| x * 2).filter(|x| x % 3 == 0).map(|x| x / 2).collect();
    assert_eq!(got, want);
}

#[test]
fn many_concurrent_jobs_complete() {
    let c = ctx(Deploy::Local { cores: 4 }, 8);
    let futures: Vec<_> = (0..20)
        .map(|k| {
            let rdd = c
                .parallelize((0..200u64).collect())
                .map(move |v| v.wrapping_mul(k + 1));
            c.collect_async(&rdd)
        })
        .collect();
    for (k, f) in futures.into_iter().enumerate() {
        let got = f.get();
        assert_eq!(got.len(), 200);
        assert_eq!(got[2], 2 * (k as u64 + 1));
    }
}

#[test]
fn pipeline_composition_end_to_end() {
    let c = ctx(Deploy::Local { cores: 2 }, 4);
    let p = Pipeline::<u32, u32>::new("inc", |_, r| r.map(|v| v + 1))
        .then("expand", |_, r| r.flat_map(|v| vec![v, v]))
        .then("sum-parts", |_, r| r.map_partitions(|_, xs| vec![xs.iter().sum::<u32>()]));
    let parts = p.run(&c, c.parallelize((0..100).collect()));
    let total: u32 = parts.iter().sum();
    assert_eq!(total, 2 * (1..=100).sum::<u32>());
}

#[test]
fn des_cluster_beats_single_thread_on_parallel_work() {
    // identical work replayed against two topologies: the 20-core cluster
    // must simulate ~an order of magnitude faster than 1 core.
    let work = |deploy: Deploy| {
        let c = ctx(deploy, 40);
        let rdd = c.parallelize_with((0..40u64).collect(), 40).map(|s| {
            // ~0.3 ms of real work per task
            let mut acc = s;
            for i in 0..60_000u64 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            acc
        });
        let _ = c.collect(&rdd);
        c.report()
    };
    let single = work(Deploy::SingleThread);
    let cluster = work(Deploy::Cluster { workers: 5, cores_per_worker: 4 });
    assert!(
        cluster.sim_makespan_s < single.sim_makespan_s / 5.0,
        "cluster {} vs single {}",
        cluster.sim_makespan_s,
        single.sim_makespan_s
    );
}

#[test]
fn async_submission_overlaps_in_des_sync_does_not() {
    // two identical jobs; sync = submit/get/submit/get, async = submit both.
    let run = |do_async: bool| {
        let c = ctx(Deploy::Cluster { workers: 4, cores_per_worker: 4 }, 8);
        let make = || {
            c.parallelize_with((0..8u64).collect(), 8).map(|s| {
                let mut acc = s;
                for i in 0..50_000u64 {
                    acc = acc.wrapping_mul(31).wrapping_add(i);
                }
                acc
            })
        };
        if do_async {
            let f1 = c.collect_async(&make());
            let f2 = c.collect_async(&make());
            let _ = (f1.get(), f2.get());
        } else {
            let _ = c.collect(&make());
            let _ = c.collect(&make());
        }
        c.report().sim_makespan_s
    };
    let sync_s = run(false);
    let async_s = run(true);
    // 16 cores, 8 tasks per job: async packs both jobs concurrently.
    assert!(
        async_s < sync_s * 0.75,
        "async {async_s} should beat sync {sync_s} on an idle-heavy topology"
    );
}

#[test]
fn broadcast_value_visible_in_tasks() {
    let c = ctx(Deploy::Local { cores: 2 }, 4);
    let table = c.broadcast(vec![10i64, 20, 30], 24);
    let t2 = table.clone();
    let rdd = c
        .parallelize((0..9usize).collect())
        .uses_broadcast(&table)
        .map(move |i| t2.value()[i % 3]);
    let got = c.collect(&rdd);
    assert_eq!(got, vec![10, 20, 30, 10, 20, 30, 10, 20, 30]);
    // dep recorded on the job
    let jobs = c.events().jobs();
    assert!(jobs.iter().any(|j| j.broadcast_deps.iter().any(|(id, _)| *id == table.id())));
}

#[test]
fn sample_is_deterministic_and_roughly_proportional() {
    let c = ctx(Deploy::Local { cores: 2 }, 8);
    let rdd = c.parallelize((0..10_000i64).collect()).sample(0.3, 99);
    let a = c.collect(&rdd);
    let b = c.collect(&rdd);
    assert_eq!(a, b, "sampling must be deterministic in (seed, partition)");
    let frac = a.len() as f64 / 10_000.0;
    assert!((frac - 0.3).abs() < 0.05, "kept {frac}");
    // elements keep order and come from the source
    assert!(a.windows(2).all(|w| w[0] < w[1]));
}

#[test]
fn zip_with_index_is_global_and_ordered() {
    let c = ctx(Deploy::Local { cores: 2 }, 5);
    let data: Vec<char> = "abcdefghijk".chars().collect();
    let rdd = c.parallelize(data.clone()).zip_with_index();
    let got = c.collect(&rdd);
    for (i, (idx, v)) in got.iter().enumerate() {
        assert_eq!(*idx, i);
        assert_eq!(*v, data[i]);
    }
}

#[test]
fn reduce_by_key_matches_sequential() {
    let c = ctx(Deploy::Local { cores: 2 }, 6);
    let rdd = c
        .parallelize((0..1000u64).collect())
        .key_by(|x| x % 7);
    let mut got = c.reduce_by_key(&rdd, |a, b| a + b);
    got.sort_by_key(|(k, _)| *k);
    let mut want = vec![(0u64, 0u64); 7];
    for x in 0..1000u64 {
        want[(x % 7) as usize].0 = x % 7;
        want[(x % 7) as usize].1 += x;
    }
    assert_eq!(got, want);
}

#[test]
fn group_by_key_collects_all_values() {
    let c = ctx(Deploy::Local { cores: 2 }, 4);
    let rdd = c.parallelize((0..100usize).collect()).key_by(|x| x % 3);
    let mut groups = c.group_by_key(&rdd);
    groups.sort_by_key(|(k, _)| *k);
    assert_eq!(groups.len(), 3);
    for (k, vs) in &groups {
        assert_eq!(vs.len(), if *k == 0 { 34 } else { 33 });
        assert!(vs.windows(2).all(|w| w[0] < w[1]), "per-partition order kept");
        assert!(vs.iter().all(|v| v % 3 == *k));
    }
}

#[test]
fn flaky_task_retried_and_job_succeeds() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    // fail the first two attempts of partition 1, then succeed — the
    // "resilient" in RDD (Spark task.maxFailures semantics)
    let c = ctx(Deploy::Local { cores: 2 }, 4);
    let failures = Arc::new(AtomicUsize::new(0));
    let f2 = Arc::clone(&failures);
    let rdd = c
        .parallelize_with((0..40i64).collect(), 4)
        .map_partitions(move |p, xs| {
            if p == 1 && f2.fetch_add(1, Ordering::SeqCst) < 2 {
                panic!("injected fault on partition 1");
            }
            xs
        });
    let got = c.collect(&rdd);
    assert_eq!(got, (0..40).collect::<Vec<_>>());
    // event log records the retries
    let tasks = c.events().tasks();
    let p1 = tasks.iter().find(|t| t.partition == 1).unwrap();
    assert_eq!(p1.attempts, 3, "partition 1 should have taken 3 attempts");
    assert!(tasks.iter().filter(|t| t.partition != 1).all(|t| t.attempts == 1));
}

#[test]
fn permanently_failing_task_fails_job_not_process() {
    let c = Context::new(
        EngineConfig::new(Deploy::Local { cores: 2 })
            .with_default_parallelism(4)
            .with_max_task_attempts(2),
    );
    let rdd = c
        .parallelize_with((0..8i64).collect(), 4)
        .map(|x: i64| if x == 5 { panic!("poison element {x}") } else { x });
    let err = c.try_collect(&rdd).unwrap_err();
    assert!(err.reason.contains("poison element 5"), "{err}");
    assert!(err.reason.contains("2 attempts"), "{err}");
    // the context is still usable for new jobs afterwards
    let ok = c.collect(&c.parallelize(vec![1, 2, 3]));
    assert_eq!(ok, vec![1, 2, 3]);
}

#[test]
fn report_utilization_bounded() {
    let c = ctx(Deploy::Cluster { workers: 2, cores_per_worker: 2 }, 8);
    let rdd = c.parallelize((0..64u64).collect()).map(|v| v + 1);
    let _ = c.collect(&rdd);
    let rep = c.report();
    assert!(rep.sim_utilization >= 0.0 && rep.sim_utilization <= 1.0);
    assert!(rep.sim_makespan_s >= 0.0);
    assert_eq!(rep.topology, "cluster(2x2)");
}
