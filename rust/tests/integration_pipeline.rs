//! Pipeline-level integration: the paper's §3 pipelines over the engine,
//! including DES behaviour of the A-cases (Fig. 4's qualitative shape).

use std::sync::Arc;

use parccm::ccm::backend::ComputeBackend;
use parccm::ccm::driver::{Case, RunSpec, TablePolicy};
use parccm::ccm::params::{CcmParams, Scenario};
use parccm::ccm::pipeline::{
    ccm_transform_rdd, table_pipeline, table_pipeline_mode, table_transform_rdd, CcmProblem,
    TableMode,
};
use parccm::ccm::table::DistanceTable;
use parccm::ccm::subsample::draw_samples;
use parccm::engine::{Context, Deploy, EngineConfig};
use parccm::native::NativeBackend;
use parccm::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
use parccm::util::rng::Rng;

fn backend() -> Arc<dyn ComputeBackend> {
    Arc::new(NativeBackend)
}

#[test]
fn table_cuts_task_time_vs_bruteforce() {
    // the paper's central claim (§3.2/§4.1): the distance indexing table
    // removes most of the per-subsample k-NN cost. Compare total task
    // seconds (scheduling-independent).
    let (x, y) = coupled_logistic(900, CoupledLogisticParams::default());
    // r must be large enough to amortize the one-off table build (the
    // paper uses r=500; its >80% cut is at that amortization — asserted
    // by `cargo bench --bench ablation` at scale).
    let s = Scenario {
        series_len: 900,
        r: 100,
        ls: vec![400],
        es: vec![2],
        taus: vec![1],
        theiler: 0,
        seed: 5,
        partitions: 6,
    };
    let brute =
        RunSpec::new(Case::A2, &s, &y, &x).deploy(Deploy::Local { cores: 2 }).run(backend());
    let tabled =
        RunSpec::new(Case::A4, &s, &y, &x).deploy(Deploy::Local { cores: 2 }).run(backend());
    let cut = 1.0 - tabled.report.total_task_s / brute.report.total_task_s;
    assert!(
        cut > 0.4,
        "table should cut >40% of task time at L=400,n~900,r=100 (got {:.1}%, brute {:.3}s table {:.3}s)",
        cut * 100.0,
        brute.report.total_task_s,
        tabled.report.total_task_s
    );
}

#[test]
fn fig4_qualitative_ordering_holds() {
    // A5 <= A4 <= A2 and A5 <= A3 <= A2 in simulated cluster makespan;
    // all engine cases beat A1 by a wide margin on the 5x4 topology.
    let (x, y) = coupled_logistic(600, CoupledLogisticParams::default());
    let s = Scenario {
        series_len: 600,
        r: 48, // enough realizations to amortize the table build
        ls: vec![100, 300],
        es: vec![2, 4],
        taus: vec![1],
        theiler: 0,
        seed: 3,
        partitions: 8,
    };
    let deploy = Deploy::paper_cluster();
    let mut makespans = std::collections::HashMap::new();
    for case in Case::ALL {
        let rep = RunSpec::new(case, &s, &y, &x).deploy(deploy.clone()).run(backend());
        makespans.insert(case, rep.report.sim_makespan_s);
    }
    let get = |c: Case| makespans[&c];
    assert!(get(Case::A5) <= get(Case::A4) * 1.05, "async table should not lose to sync table");
    assert!(get(Case::A3) <= get(Case::A2) * 1.05, "async should not lose to sync");
    assert!(get(Case::A4) < get(Case::A2), "table must beat brute force");
    assert!(
        get(Case::A5) < get(Case::A1) / 5.0,
        "full parallel {} should be far below single-threaded {}",
        get(Case::A5),
        get(Case::A1)
    );
}

#[test]
fn async_table_case_overlaps_jobs() {
    // In A5 the per-L jobs of one (E, tau) group are submitted while
    // earlier ones still run; the event log must show overlapping spans.
    let (x, y) = coupled_logistic(500, CoupledLogisticParams::default());
    let s = Scenario {
        series_len: 500,
        r: 16,
        ls: vec![60, 120, 240],
        es: vec![2],
        taus: vec![1],
        theiler: 0,
        seed: 11,
        partitions: 8,
    };
    // run engine case manually to keep the context (RunSpec::run drops it)
    let ctx = Context::new(
        EngineConfig::new(Deploy::Local { cores: 2 }).with_default_parallelism(s.partitions),
    );
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let n = problem.emb.n;
    let size = problem.size_bytes();
    let pb = ctx.broadcast(problem, size);
    let table = table_pipeline(&ctx, &pb, s.partitions);
    let master = Rng::new(s.seed);
    let mut futures = Vec::new();
    for &l in &s.ls {
        let samples = draw_samples(&master, CcmParams::new(2, 1, l), n, s.r);
        let rdd = ctx.parallelize_with(samples, s.partitions);
        let out = table_transform_rdd(&ctx, rdd, &pb, &table, backend());
        futures.push(ctx.collect_async(&out));
    }
    let mut total = 0;
    for f in futures {
        total += f.get().len();
    }
    assert_eq!(total, 3 * s.r);

    // overlap check: some job must start before the previous one finishes
    let jobs: Vec<_> = ctx
        .events()
        .jobs()
        .into_iter()
        .filter(|j| j.name.contains("map_partitions"))
        .collect();
    assert!(jobs.len() >= 3);
    let mut overlapped = false;
    for w in jobs.windows(2) {
        if w[1].submit_rel < w[0].finish_rel {
            overlapped = true;
        }
    }
    assert!(overlapped, "async submission should overlap job spans: {jobs:?}");
}

#[test]
fn truncated_table_matches_full_with_smaller_broadcast() {
    // ISSUE 1 acceptance: truncated-table size_bytes is O(n * P) and the
    // skills agree bit-exactly with the full layout through the whole
    // engine stack.
    let (x, y) = coupled_logistic(700, CoupledLogisticParams::default());
    let ctx = Context::new(EngineConfig::new(Deploy::Local { cores: 2 }).with_default_parallelism(6));
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let n = problem.emb.n;
    let size = problem.size_bytes();
    let pb = ctx.broadcast(problem, size);
    let samples = draw_samples(&Rng::new(33), CcmParams::new(2, 1, 200), n, 24);

    let full = table_pipeline_mode(&ctx, &pb, 6, TableMode::Full);
    let prefix = DistanceTable::auto_prefix(n, 200);
    let trunc = table_pipeline_mode(&ctx, &pb, 6, TableMode::Truncated { prefix });
    assert!(prefix < n - 1, "auto prefix must truncate at this density");
    assert_eq!(
        trunc.size_bytes(),
        n * prefix * 4 + n * parccm::EMAX * 4,
        "O(n*P) + manifold"
    );
    assert!(trunc.size_bytes() < full.size_bytes() / 2);

    let a = ctx.collect(&table_transform_rdd(
        &ctx,
        ctx.parallelize_with(samples.clone(), 6),
        &pb,
        &full,
        backend(),
    ));
    let b = ctx.collect(&table_transform_rdd(
        &ctx,
        ctx.parallelize_with(samples, 6),
        &pb,
        &trunc,
        backend(),
    ));
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.sample_id, rb.sample_id);
        assert_eq!(ra.rho.to_bits(), rb.rho.to_bits(), "truncation must be bit-exact");
    }
}

#[test]
fn driver_policies_agree_through_table_cases() {
    let (x, y) = coupled_logistic(500, CoupledLogisticParams::default());
    let s = Scenario {
        series_len: 500,
        r: 10,
        ls: vec![80, 200],
        es: vec![2],
        taus: vec![1],
        theiler: 0,
        seed: 13,
        partitions: 4,
    };
    let deploy = Deploy::Local { cores: 2 };
    let sort = |mut rows: Vec<parccm::ccm::SkillRow>| {
        rows.sort_by_key(|r| (r.params.l, r.sample_id));
        rows
    };
    let full = sort(
        RunSpec::new(Case::A4, &s, &y, &x)
            .deploy(deploy.clone())
            .policy(TablePolicy::Full)
            .run(backend())
            .skills,
    );
    for policy in [TablePolicy::TruncatedAuto, TablePolicy::Truncated(16)] {
        let got = sort(
            RunSpec::new(Case::A4, &s, &y, &x)
                .deploy(deploy.clone())
                .policy(policy)
                .run(backend())
                .skills,
        );
        assert_eq!(full.len(), got.len());
        for (a, b) in full.iter().zip(&got) {
            assert_eq!(a.rho.to_bits(), b.rho.to_bits(), "{policy:?} diverged");
        }
    }
}

#[test]
fn pipeline_stage_equivalence_bruteforce_vs_table_at_scale() {
    let (x, y) = coupled_logistic(700, CoupledLogisticParams::default());
    let ctx = Context::new(EngineConfig::new(Deploy::Local { cores: 2 }).with_default_parallelism(6));
    let problem = CcmProblem::new(&y, &x, 3, 2, 0.0);
    let n = problem.emb.n;
    let size = problem.size_bytes();
    let pb = ctx.broadcast(problem, size);
    let samples = draw_samples(&Rng::new(21), CcmParams::new(3, 2, 250), n, 20);

    let brute = ctx.collect(&ccm_transform_rdd(
        &ctx,
        ctx.parallelize_with(samples.clone(), 6),
        &pb,
        backend(),
    ));
    let table = table_pipeline(&ctx, &pb, 6);
    let tabled = ctx.collect(&table_transform_rdd(
        &ctx,
        ctx.parallelize_with(samples, 6),
        &pb,
        &table,
        backend(),
    ));
    assert_eq!(brute.len(), tabled.len());
    for (a, b) in brute.iter().zip(&tabled) {
        assert!((a.rho - b.rho).abs() < 1e-5, "{} vs {}", a.rho, b.rho);
    }
}
