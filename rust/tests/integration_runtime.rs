//! Runtime integration: load the AOT artifacts, execute them via PJRT,
//! and cross-check the XLA backend against the native one — this is where
//! the Rust side inherits the pytest-verified Pallas semantics.
//!
//! Requires `make artifacts` (skips, loudly, if artifacts/ is absent).

use std::sync::Arc;

use parccm::ccm::backend::{ComputeBackend, NeighborPanels};
use parccm::ccm::embedding::Embedding;
use parccm::ccm::knn::knn_batch;
use parccm::ccm::params::CcmParams;
use parccm::ccm::pipeline::CcmProblem;
use parccm::ccm::subsample::draw_samples;
use parccm::native::NativeBackend;
use parccm::runtime::{artifacts_available, XlaBackend, DEFAULT_ARTIFACTS_DIR};
use parccm::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
use parccm::util::rng::Rng;
use parccm::{EMAX, KMAX};

fn artifacts_dir() -> Option<String> {
    // tests run from the crate root
    if artifacts_available(DEFAULT_ARTIFACTS_DIR) {
        Some(DEFAULT_ARTIFACTS_DIR.to_string())
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn xla_backend() -> Option<XlaBackend> {
    artifacts_dir().map(|d| XlaBackend::from_dir(&d, 1).expect("starting XLA service"))
}

#[test]
fn distance_matrix_matches_native() {
    let Some(xla) = xla_backend() else { return };
    let mut rng = Rng::new(1);
    let n = 100; // deliberately not a bucket size: exercises padding
    let mut vecs = vec![0.0f32; n * EMAX];
    for i in 0..n {
        for l in 0..3 {
            vecs[i * EMAX + l] = rng.f32();
        }
    }
    let got = xla.distance_matrix(&vecs, n);
    let want = NativeBackend.distance_matrix(&vecs, n);
    assert_eq!(got.len(), want.len());
    for i in 0..n * n {
        assert!(
            (got[i] - want[i]).abs() < 1e-4,
            "distance [{i}]: xla {} vs native {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn cross_map_matches_native() {
    let Some(xla) = xla_backend() else { return };
    let (x, y) = coupled_logistic(500, CoupledLogisticParams::default());
    for (e, tau, l) in [(2usize, 1usize, 150usize), (4, 2, 300), (1, 1, 60)] {
        let problem = CcmProblem::new(&y, &x, e, tau, 0.0);
        let samples = draw_samples(&Rng::new(3), CcmParams::new(e, tau, l), problem.emb.n, 3);
        for s in &samples {
            let input = problem.input_for(s);
            let a = xla.cross_map(&input);
            let b = NativeBackend.cross_map(&input);
            assert!(
                (a.rho - b.rho).abs() < 1e-4,
                "(E={e},tau={tau},L={l}) sample {}: xla rho {} vs native {}",
                s.sample_id,
                a.rho,
                b.rho
            );
            let max_diff = a
                .preds
                .iter()
                .zip(&b.preds)
                .map(|(p, q)| (p - q).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 1e-3, "pred divergence {max_diff}");
        }
    }
}

#[test]
fn simplex_tail_matches_native() {
    let Some(xla) = xla_backend() else { return };
    let (x, y) = coupled_logistic(400, CoupledLogisticParams::default());
    let emb = Embedding::new(&y, 3, 1);
    let targets = emb.align_targets(&x);
    let mut rng = Rng::new(5);
    let rows = rng.sample_indices(emb.n, 120);
    let mut lib_vecs = Vec::new();
    let mut lib_targets = Vec::new();
    let mut lib_times = Vec::new();
    for &r in &rows {
        lib_vecs.extend_from_slice(emb.point(r));
        lib_targets.push(targets[r]);
        lib_times.push(emb.time_of(r) as f32);
    }
    let pred_times: Vec<f32> = (0..emb.n).map(|i| emb.time_of(i) as f32).collect();
    let (dvals, tvals) =
        knn_batch(&emb.vecs, &pred_times, &lib_vecs, &lib_targets, &lib_times, 0.0);
    let panels = NeighborPanels { dvals, tvals, n_pred: emb.n };
    let a = xla.simplex_tail(&panels, &targets, 3);
    let b = NativeBackend.simplex_tail(&panels, &targets, 3);
    assert!((a.rho - b.rho).abs() < 1e-4, "xla {} vs native {}", a.rho, b.rho);
}

#[test]
fn service_handles_concurrent_callers() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = Arc::new(XlaBackend::from_dir(&dir, 2).expect("pool of 2"));
    let (x, y) = coupled_logistic(400, CoupledLogisticParams::default());
    let problem = Arc::new(CcmProblem::new(&y, &x, 2, 1, 0.0));
    let samples = draw_samples(&Rng::new(11), CcmParams::new(2, 1, 100), problem.emb.n, 8);
    let native: Vec<f32> = samples
        .iter()
        .map(|s| NativeBackend.cross_map(&problem.input_for(s)).rho)
        .collect();

    let handles: Vec<_> = samples
        .iter()
        .cloned()
        .map(|s| {
            let xla = Arc::clone(&xla);
            let problem = Arc::clone(&problem);
            std::thread::spawn(move || xla.cross_map(&problem.input_for(&s)).rho)
        })
        .collect();
    for (h, want) in handles.into_iter().zip(native) {
        let got = h.join().unwrap();
        assert!((got - want).abs() < 1e-4, "{got} vs {want}");
    }
}

#[test]
fn kmax_emax_contract() {
    // guard: manifest constants must match the binary (Manifest::load
    // enforces it; this test just ensures artifacts on disk are current).
    let Some(dir) = artifacts_dir() else { return };
    let manifest = parccm::runtime::Manifest::load(&dir).expect("manifest");
    assert!(!manifest.artifacts.is_empty());
    assert_eq!(EMAX, 8);
    assert_eq!(KMAX, 11);
}
