//! End-to-end tests of the process-separated backend: real forked
//! `parccm worker` processes (via `CARGO_BIN_EXE_parccm`), the JSON wire
//! protocol, shard broadcasts, and worker-death recovery. Each test arms
//! a [`Watchdog`] so a hung worker fails the CI job fast instead of
//! stalling it. (`ProcessBackend` is the pipe-transport `ClusterBackend`
//! since PR 3; TCP/replication coverage lives in
//! `tests/integration_cluster.rs`.)

use std::sync::Arc;
use std::time::Duration;

use parccm::ccm::backend::{ComputeBackend, TaskArena};
use parccm::ccm::driver::{Case, RunSpec, TablePolicy};
use parccm::ccm::params::{CcmParams, Scenario};
use parccm::ccm::pipeline::CcmProblem;
use parccm::ccm::process::ProcessBackend;
use parccm::ccm::subsample::draw_samples;
use parccm::ccm::table::DistanceTable;
use parccm::engine::Deploy;
use parccm::native::NativeBackend;
use parccm::util::rng::Rng;
use parccm::util::watchdog::Watchdog;

const TEST_TIMEOUT: Duration = Duration::from_secs(180);

fn spawn_backend(workers: usize) -> Arc<ProcessBackend> {
    Arc::new(
        ProcessBackend::with_command(env!("CARGO_BIN_EXE_parccm"), workers)
            .expect("spawning worker processes"),
    )
}

#[test]
fn process_cross_map_bit_identical_to_native() {
    let _guard = Watchdog::arm("process_cross_map_bit_identical", TEST_TIMEOUT);
    let pb = spawn_backend(2);
    assert_eq!(pb.num_workers(), 2);
    let (x, y) = parccm::timeseries::generators::coupled_logistic(
        400,
        parccm::timeseries::generators::CoupledLogisticParams::default(),
    );
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let samples = draw_samples(&Rng::new(3), CcmParams::new(2, 1, 120), problem.emb.n, 6);
    let native = NativeBackend;
    let mut arena_p = TaskArena::new();
    let mut arena_n = TaskArena::new();
    for s in &samples {
        let input = problem.input_for(s);
        let rho_p = pb.cross_map_into(&input, &mut arena_p);
        let rho_n = native.cross_map_into(&input, &mut arena_n);
        assert_eq!(rho_p.to_bits(), rho_n.to_bits(), "wire roundtrip must be exact");
        assert_eq!(arena_p.preds, arena_n.preds);
    }
}

#[test]
fn process_shard_chunks_bit_identical_to_local() {
    let _guard = Watchdog::arm("process_shard_chunks_bit_identical", TEST_TIMEOUT);
    let pb = spawn_backend(2);
    let (x, y) = parccm::timeseries::generators::coupled_logistic(
        300,
        parccm::timeseries::generators::CoupledLogisticParams::default(),
    );
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let table = DistanceTable::build_truncated(&problem.emb, 32);
    let sharded = table.shard(3);
    let rows: Vec<usize> = (0..problem.emb.n).step_by(4).collect();
    let mut arena_p = TaskArena::new();
    let mut arena_n = TaskArena::new();
    for shard in sharded.shards() {
        let mut remote = Vec::new();
        let mut local = Vec::new();
        pb.shard_chunk_into(shard, &problem.targets, 0.0, &rows, 2, &mut arena_p, &mut remote);
        NativeBackend.shard_chunk_into(
            shard,
            &problem.targets,
            0.0,
            &rows,
            2,
            &mut arena_n,
            &mut local,
        );
        assert_eq!(remote.len(), shard.num_rows());
        assert_eq!(remote, local, "shard {} chunk must survive the wire", shard.shard_id);
    }
}

#[test]
fn process_backend_runs_a4_style_scenario_end_to_end() {
    let _guard = Watchdog::arm("process_backend_a4_scenario", TEST_TIMEOUT);
    // the acceptance scenario: a synchronous sharded-table case (A4
    // style) executed entirely through >= 2 worker processes, checked
    // against the single-threaded A1 reference and bit-identical to the
    // in-process sharded run.
    let scenario = Scenario::smoke();
    let (x, y) = parccm::timeseries::generators::coupled_logistic(
        scenario.series_len,
        parccm::timeseries::generators::CoupledLogisticParams::default(),
    );
    let deploy = Deploy::Local { cores: 2 };

    let a1 = RunSpec::new(Case::A1, &scenario, &y, &x)
        .deploy(deploy.clone())
        .run(Arc::new(NativeBackend));
    let in_process = RunSpec::new(Case::A4, &scenario, &y, &x)
        .deploy(deploy.clone())
        .policy(TablePolicy::TruncatedAuto)
        .shards(3)
        .run(Arc::new(NativeBackend));

    let pb = spawn_backend(2);
    assert!(pb.num_workers() >= 2);
    let backend: Arc<dyn ComputeBackend> = pb.clone();
    let via_workers = RunSpec::new(Case::A4, &scenario, &y, &x)
        .deploy(deploy)
        .policy(TablePolicy::TruncatedAuto)
        .shards(3)
        .run(backend);

    let key = |r: &parccm::ccm::result::SkillRow| {
        (r.params.e, r.params.tau, r.params.l, r.sample_id)
    };
    let mut a1 = a1.skills;
    a1.sort_by_key(key);
    let mut local = in_process.skills;
    local.sort_by_key(key);
    let mut remote = via_workers.skills;
    remote.sort_by_key(key);
    assert_eq!(remote.len(), scenario.combos().len() * scenario.r);
    assert_eq!(remote.len(), a1.len());
    for ((a, l), r) in a1.iter().zip(&local).zip(&remote) {
        assert_eq!(key(a), key(r));
        assert!(
            (a.rho - r.rho).abs() < 1e-5,
            "process-backend rho {} vs A1 {} at {:?}",
            r.rho,
            a.rho,
            key(a)
        );
        assert_eq!(
            l.rho.to_bits(),
            r.rho.to_bits(),
            "process-backend rho must be bit-identical to in-process sharded at {:?}",
            key(a)
        );
    }
    assert_eq!(pb.run_counters().respawns, 0, "healthy run must not recycle workers");
}

#[test]
fn worker_kill_requeues_tasks_on_fresh_workers() {
    let _guard = Watchdog::arm("worker_kill_requeues", TEST_TIMEOUT);
    let pb = spawn_backend(2);
    let (x, y) = parccm::timeseries::generators::coupled_logistic(
        300,
        parccm::timeseries::generators::CoupledLogisticParams::default(),
    );
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let samples = draw_samples(&Rng::new(5), CcmParams::new(2, 1, 80), problem.emb.n, 4);
    let native = NativeBackend;
    let mut arena_p = TaskArena::new();
    let mut arena_n = TaskArena::new();

    // warm up: broadcasts shipped, every result correct
    for s in &samples {
        let input = problem.input_for(s);
        let rho_p = pb.cross_map_into(&input, &mut arena_p);
        assert_eq!(rho_p.to_bits(), native.cross_map_into(&input, &mut arena_n).to_bits());
    }

    // kill every live worker out from under the backend
    let pids = pb.worker_pids();
    assert_eq!(pids.len(), 2, "both workers idle before the kill");
    for pid in &pids {
        let status = std::process::Command::new("kill")
            .args(["-9", &pid.to_string()])
            .status()
            .expect("running kill");
        assert!(status.success(), "kill -9 {pid}");
    }
    std::thread::sleep(std::time::Duration::from_millis(200));

    // tasks must requeue onto respawned workers, with broadcasts
    // re-shipped from the driver-side payload cache, and stay exact.
    // (Shard-affine dispatch touches only the preferred worker, so a
    // single respawn is the guaranteed floor even with every pid killed.)
    for s in &samples {
        let input = problem.input_for(s);
        let rho_p = pb.cross_map_into(&input, &mut arena_p);
        assert_eq!(rho_p.to_bits(), native.cross_map_into(&input, &mut arena_n).to_bits());
    }
    assert!(pb.run_counters().respawns >= 1, "a killed worker must have been replaced");
    assert_eq!(pb.num_workers(), 2, "pool back at target size");
    assert!(
        pb.worker_pids().iter().any(|p| !pids.contains(p)),
        "at least one fresh worker pid expected after the kill"
    );
}
