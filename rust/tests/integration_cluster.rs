//! End-to-end tests of the transport-generic cluster runtime: TCP
//! loopback parity with the pipe transport, shard replication with
//! zero-re-ship requeue, last-replica re-broadcast, wire-version
//! negotiation (incl. the doctored-handshake regression), and broadcast
//! eviction. Every test arms a [`Watchdog`] so a hung worker fails the CI
//! job fast instead of stalling it.

use std::sync::Arc;
use std::time::Duration;

use parccm::ccm::backend::{ComputeBackend, TaskArena};
use parccm::ccm::chaos::ChaosProfile;
use parccm::ccm::cluster::{
    problem_wire_id, ClusterBackend, ClusterOptions, OnExhausted, TEST_HELLO_V_ENV,
};
use parccm::ccm::driver::{Case, ReduceMode, RunSpec, TablePolicy};
use parccm::ccm::params::{CcmParams, Scenario};
use parccm::ccm::pipeline::{f32_ulp_distance, CcmProblem};
use parccm::ccm::subsample::draw_samples;
use parccm::ccm::table::DistanceTable;
use parccm::ccm::transport::{TransportKind, MIN_WIRE_VERSION, WIRE_VERSION};
use parccm::engine::Deploy;
use parccm::native::NativeBackend;
use parccm::util::rng::Rng;
use parccm::util::watchdog::Watchdog;

const TEST_TIMEOUT: Duration = Duration::from_secs(180);

fn spawn(kind: TransportKind, workers: usize, replicas: usize) -> Arc<ClusterBackend> {
    Arc::new(
        ClusterBackend::with_options(
            env!("CARGO_BIN_EXE_parccm"),
            ClusterOptions { transport: kind, workers, replicas, ..ClusterOptions::default() },
        )
        .expect("spawning worker processes"),
    )
}

fn series(n: usize) -> (Vec<f32>, Vec<f32>) {
    parccm::timeseries::generators::coupled_logistic(
        n,
        parccm::timeseries::generators::CoupledLogisticParams::default(),
    )
}

fn kill9(pid: u32) {
    let status = std::process::Command::new("kill")
        .args(["-9", &pid.to_string()])
        .status()
        .expect("running kill");
    assert!(status.success(), "kill -9 {pid}");
}

#[test]
fn tcp_cross_map_bit_identical_to_pipe_and_native() {
    let _guard = Watchdog::arm("tcp_cross_map_bit_identical", TEST_TIMEOUT);
    let pipe = spawn(TransportKind::Pipe, 2, 1);
    let tcp = spawn(TransportKind::Tcp, 2, 1);
    assert_eq!(tcp.transport_kind(), TransportKind::Tcp);
    let (x, y) = series(400);
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let samples = draw_samples(&Rng::new(3), CcmParams::new(2, 1, 120), problem.emb.n, 6);
    let native = NativeBackend;
    let mut arena_pipe = TaskArena::new();
    let mut arena_tcp = TaskArena::new();
    let mut arena_n = TaskArena::new();
    for s in &samples {
        let input = problem.input_for(s);
        let rho_pipe = pipe.cross_map_into(&input, &mut arena_pipe);
        let rho_tcp = tcp.cross_map_into(&input, &mut arena_tcp);
        let rho_n = native.cross_map_into(&input, &mut arena_n);
        assert_eq!(rho_tcp.to_bits(), rho_n.to_bits(), "tcp wire roundtrip must be exact");
        assert_eq!(rho_pipe.to_bits(), rho_tcp.to_bits(), "transports must agree bitwise");
        assert_eq!(arena_pipe.preds, arena_tcp.preds);
        assert_eq!(arena_tcp.preds, arena_n.preds);
    }
    assert_eq!(pipe.run_counters().respawns, 0);
    assert_eq!(tcp.run_counters().respawns, 0);
}

#[test]
fn tcp_sharded_scenario_bit_identical_to_in_process() {
    // the acceptance scenario on the TCP transport with replication: a
    // sharded A4 case through 2 real TCP workers, bit-identical to the
    // in-process sharded run (which is itself pinned to A1/monolithic).
    let _guard = Watchdog::arm("tcp_sharded_scenario", TEST_TIMEOUT);
    let scenario = Scenario::smoke();
    let (x, y) = series(scenario.series_len);
    let deploy = Deploy::Local { cores: 2 };

    let in_process = RunSpec::new(Case::A4, &scenario, &y, &x)
        .deploy(deploy.clone())
        .policy(TablePolicy::TruncatedAuto)
        .shards(3)
        .run(Arc::new(NativeBackend));

    let tcp = spawn(TransportKind::Tcp, 2, 2);
    let backend: Arc<dyn ComputeBackend> = tcp.clone();
    let via_workers = RunSpec::new(Case::A4, &scenario, &y, &x)
        .deploy(deploy)
        .policy(TablePolicy::TruncatedAuto)
        .shards(3)
        .run(backend);

    let key = |r: &parccm::ccm::result::SkillRow| {
        (r.params.e, r.params.tau, r.params.l, r.sample_id)
    };
    let mut local = in_process.skills;
    local.sort_by_key(key);
    let mut remote = via_workers.skills;
    remote.sort_by_key(key);
    assert_eq!(remote.len(), scenario.combos().len() * scenario.r);
    assert_eq!(remote.len(), local.len());
    for (l, r) in local.iter().zip(&remote) {
        assert_eq!(key(l), key(r));
        assert_eq!(
            l.rho.to_bits(),
            r.rho.to_bits(),
            "tcp sharded rho must be bit-identical to in-process at {:?}",
            key(l)
        );
    }
    assert_eq!(tcp.run_counters().respawns, 0, "healthy run must not recycle workers");
    // the driver evicts each problem's broadcasts once harvested
    assert_eq!(tcp.cached_payloads(), 0, "payload cache must be drained");
    assert!(tcp.run_counters().evictions > 0, "workers must have been told to evict");
}

#[test]
fn replicated_shard_requeue_ships_zero_bytes() {
    // the tentpole guarantee: with --replicas 2, killing a worker that
    // holds a shard requeues its tasks onto the surviving replica with
    // ZERO additional broadcast bytes (no re-ship, no re-broadcast).
    let _guard = Watchdog::arm("replicated_shard_requeue", TEST_TIMEOUT);
    let pb = spawn(TransportKind::Tcp, 2, 2);
    assert_eq!(pb.replicas(), 2);
    let (x, y) = series(300);
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let table = DistanceTable::build_truncated(&problem.emb, 32);
    let sharded = table.shard(2);
    let rows: Vec<usize> = (0..problem.emb.n).step_by(4).collect();
    let mut arena_p = TaskArena::new();
    let mut arena_n = TaskArena::new();

    let run_all = |arena_p: &mut TaskArena, arena_n: &mut TaskArena| {
        for shard in sharded.shards() {
            let mut remote = Vec::new();
            let mut local = Vec::new();
            pb.shard_chunk_into(shard, &problem.targets, 0.0, &rows, 2, arena_p, &mut remote);
            NativeBackend.shard_chunk_into(
                shard,
                &problem.targets,
                0.0,
                &rows,
                2,
                arena_n,
                &mut local,
            );
            assert_eq!(remote, local, "shard {} chunk must survive the wire", shard.shard_id);
        }
    };

    // warm up: 3 broadcast ids (2 shards + targets), each resident on
    // both workers thanks to replication
    run_all(&mut arena_p, &mut arena_n);
    assert_eq!(pb.run_counters().broadcast_ships, 6, "3 ids x 2 replicas");
    let bytes_before = pb.run_counters().broadcast_ship_bytes;
    assert!(bytes_before > 0);

    // kill one of the two (idle) workers out from under the backend
    let pids = pb.worker_pids();
    assert_eq!(pids.len(), 2, "both workers idle before the kill");
    kill9(pids[0]);
    std::thread::sleep(Duration::from_millis(200));

    // requeue onto the surviving replica: results stay exact and NOT ONE
    // additional *task-driven* broadcast byte moves — the only traffic is
    // the eager re-replication repair that restores the replication
    // factor on the respawned worker, counted on its own counters
    run_all(&mut arena_p, &mut arena_n);
    assert!(pb.run_counters().respawns >= 1, "the killed worker must have been replaced");
    assert_eq!(
        pb.run_counters().broadcast_ship_bytes,
        bytes_before,
        "requeue to a surviving replica must be zero task-driven re-ship"
    );
    assert_eq!(
        pb.run_counters().broadcast_ships,
        6,
        "no additional task-driven (id, worker) ships"
    );
    assert_eq!(pb.run_counters().rebroadcasts, 0, "a replica survived; no re-broadcast fallback");
    assert_eq!(
        pb.run_counters().repair_ships,
        3,
        "eager re-replication must restore all 3 ids on the respawned worker"
    );
    assert!(pb.run_counters().repair_ship_bytes > 0, "repair traffic is counted in bytes too");
    assert_eq!(pb.num_workers(), 2, "pool back at target size");

    // the repaired copies are real: kill the ORIGINAL survivor — the
    // respawned worker now holds every broadcast, so even this second
    // death forces no re-broadcast (the window eager repair closes)
    let survivors = pb.worker_pids();
    assert_eq!(survivors.len(), 2);
    assert!(survivors.contains(&pids[1]), "original survivor must still be pooled");
    for pid in survivors {
        if pid != pids[1] {
            continue;
        }
        kill9(pid);
        std::thread::sleep(Duration::from_millis(200));
        run_all(&mut arena_p, &mut arena_n);
        assert_eq!(pb.run_counters().rebroadcasts, 0, "repair copies must serve the second death");
        assert_eq!(pb.run_counters().broadcast_ships, 6, "still no task-driven re-ship");
    }
}

#[test]
fn last_replica_death_falls_back_to_rebroadcast() {
    // without replication, killing every holder forces the counted
    // re-broadcast path — the cost replication exists to avoid.
    let _guard = Watchdog::arm("last_replica_death", TEST_TIMEOUT);
    let pb = spawn(TransportKind::Tcp, 2, 1);
    let (x, y) = series(300);
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let samples = draw_samples(&Rng::new(5), CcmParams::new(2, 1, 80), problem.emb.n, 4);
    let native = NativeBackend;
    let mut arena_p = TaskArena::new();
    let mut arena_n = TaskArena::new();

    for s in &samples {
        let input = problem.input_for(s);
        let rho = pb.cross_map_into(&input, &mut arena_p);
        assert_eq!(rho.to_bits(), native.cross_map_into(&input, &mut arena_n).to_bits());
    }
    // replicas=1 and shard-affine dispatch: exactly one worker holds it
    assert_eq!(pb.run_counters().broadcast_ships, 1);
    let bytes_before = pb.run_counters().broadcast_ship_bytes;

    // kill every live worker: the only replica dies with them
    for pid in pb.worker_pids() {
        kill9(pid);
    }
    std::thread::sleep(Duration::from_millis(200));

    for s in &samples {
        let input = problem.input_for(s);
        let rho = pb.cross_map_into(&input, &mut arena_p);
        assert_eq!(rho.to_bits(), native.cross_map_into(&input, &mut arena_n).to_bits());
    }
    assert!(pb.run_counters().respawns >= 1);
    // >= 1: a buffered send to a not-yet-reaped dead worker can count an
    // extra (failed) ship before the error surfaces on its reply
    assert!(
        pb.run_counters().rebroadcasts >= 1,
        "the broadcast had to ship again after total loss"
    );
    assert!(
        pb.run_counters().broadcast_ship_bytes > bytes_before,
        "re-broadcast must be visible in the byte counter"
    );
}

#[test]
fn handshake_version_mismatch_fails_cleanly_naming_both_versions() {
    // regression: a worker advertising an unknown wire version must fail
    // the spawn immediately with both versions in the error — not hang,
    // not enter a requeue loop. The version is doctored via a child-only
    // env seam, so concurrent tests are unaffected.
    let _guard = Watchdog::arm("handshake_version_mismatch", Duration::from_secs(60));
    for kind in [TransportKind::Pipe, TransportKind::Tcp] {
        let err = ClusterBackend::with_options(
            env!("CARGO_BIN_EXE_parccm"),
            ClusterOptions {
                transport: kind,
                workers: 1,
                replicas: 1,
                worker_env: vec![(TEST_HELLO_V_ENV.to_string(), "99".to_string())],
                ..ClusterOptions::default()
            },
        )
        .expect_err("a v99 worker must be rejected");
        let msg = err.to_string();
        assert!(msg.contains("v99"), "{kind:?}: must name the worker's version: {msg}");
        assert!(
            msg.contains(&format!("v{WIRE_VERSION}")),
            "{kind:?}: must name the driver's version: {msg}"
        );
        assert!(
            msg.contains(&format!("v{MIN_WIRE_VERSION}")),
            "{kind:?}: must name the oldest accepted version: {msg}"
        );
        assert!(msg.contains("mismatch"), "{kind:?}: {msg}");
    }
}

#[test]
fn legacy_v1_worker_is_served_without_evict_traffic() {
    // backward-compatible negotiation: a worker advertising v1 is
    // accepted, computes bit-identically, and never receives the v2-only
    // evict message (the driver cache is still released).
    let _guard = Watchdog::arm("legacy_v1_worker", TEST_TIMEOUT);
    let pb = Arc::new(
        ClusterBackend::with_options(
            env!("CARGO_BIN_EXE_parccm"),
            ClusterOptions {
                transport: TransportKind::Pipe,
                workers: 1,
                replicas: 1,
                worker_env: vec![(TEST_HELLO_V_ENV.to_string(), "1".to_string())],
                ..ClusterOptions::default()
            },
        )
        .expect("a v1 worker must be accepted"),
    );
    let (x, y) = series(200);
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let samples = draw_samples(&Rng::new(7), CcmParams::new(2, 1, 60), problem.emb.n, 1);
    let input = problem.input_for(&samples[0]);
    let mut arena_p = TaskArena::new();
    let mut arena_n = TaskArena::new();
    let rho = pb.cross_map_into(&input, &mut arena_p);
    assert_eq!(rho.to_bits(), NativeBackend.cross_map_into(&input, &mut arena_n).to_bits());

    let pid = problem_wire_id(&problem.emb.vecs, &problem.targets, &problem.times);
    assert_eq!(pb.cached_payloads(), 1);
    pb.evict_broadcasts(&[pid]);
    assert_eq!(pb.cached_payloads(), 0, "driver-side payload must be released");
    assert_eq!(pb.run_counters().evictions, 0, "a v1 worker must never see an evict message");
}

#[test]
fn doctored_v3_worker_runs_the_v3_byte_stream_unchanged() {
    // the compatibility pin for the v4 checksum rollout: a worker
    // advertising v3 negotiates a connection WITHOUT checksum suffixes —
    // bit-identical results, zero corruption counted, zero respawns — so
    // pre-v4 peers are provably unaffected by the new framing. (A v4
    // driver talking to a v4 worker is covered by every other test in
    // this file; this one pins the downgrade path.)
    let _guard = Watchdog::arm("doctored_v3_worker", TEST_TIMEOUT);
    for kind in [TransportKind::Pipe, TransportKind::Tcp] {
        let pb = Arc::new(
            ClusterBackend::with_options(
                env!("CARGO_BIN_EXE_parccm"),
                ClusterOptions {
                    transport: kind,
                    workers: 2,
                    replicas: 1,
                    worker_env: vec![(TEST_HELLO_V_ENV.to_string(), "3".to_string())],
                    ..ClusterOptions::default()
                },
            )
            .expect("a v3 worker must be accepted"),
        );
        let (x, y) = series(250);
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let samples = draw_samples(&Rng::new(13), CcmParams::new(2, 1, 70), problem.emb.n, 3);
        let mut arena_p = TaskArena::new();
        let mut arena_n = TaskArena::new();
        for s in &samples {
            let input = problem.input_for(s);
            let rho = pb.cross_map_into(&input, &mut arena_p);
            let want = NativeBackend.cross_map_into(&input, &mut arena_n);
            assert_eq!(rho.to_bits(), want.to_bits(), "{kind:?}: v3 stream must stay exact");
            assert_eq!(arena_p.preds, arena_n.preds);
        }
        assert_eq!(
            pb.run_counters().corrupt_frames_detected,
            0,
            "{kind:?}: an un-checksummed v3 stream must never read as corrupt"
        );
        assert_eq!(pb.run_counters().respawns, 0, "{kind:?}: no connection may have died");
        assert!(pb.run_counters().evictions >= 1, "{kind:?}: v3 still understands evict");
    }
}

#[test]
fn doctored_v5_worker_pins_the_json_wire_byte_stream_unchanged() {
    // the compatibility pin for the v6 binary-wire rollout: a worker
    // advertising v5 negotiates min(5, 6) = 5, so its connections stay on
    // the checksummed JSON line wire — bit-identical results on both
    // transports, counted as json connections, zero corruption, zero
    // respawns. (The JSON lines themselves are pinned byte-identical to
    // the pre-v6 builders by the cluster unit tests; this proves the
    // negotiated downgrade path end to end through real processes.)
    let _guard = Watchdog::arm("doctored_v5_worker", TEST_TIMEOUT);
    for kind in [TransportKind::Pipe, TransportKind::Tcp] {
        let pb = Arc::new(
            ClusterBackend::with_options(
                env!("CARGO_BIN_EXE_parccm"),
                ClusterOptions {
                    transport: kind,
                    workers: 2,
                    replicas: 1,
                    worker_env: vec![(TEST_HELLO_V_ENV.to_string(), "5".to_string())],
                    ..ClusterOptions::default()
                },
            )
            .expect("a v5 worker must be accepted"),
        );
        let (x, y) = series(250);
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let samples = draw_samples(&Rng::new(19), CcmParams::new(2, 1, 70), problem.emb.n, 3);
        let mut arena_p = TaskArena::new();
        let mut arena_n = TaskArena::new();
        for s in &samples {
            let input = problem.input_for(s);
            let rho = pb.cross_map_into(&input, &mut arena_p);
            let want = NativeBackend.cross_map_into(&input, &mut arena_n);
            assert_eq!(rho.to_bits(), want.to_bits(), "{kind:?}: v5 stream must stay exact");
            assert_eq!(arena_p.preds, arena_n.preds);
        }
        let c = pb.run_counters();
        assert_eq!(c.json_connections, 2, "{kind:?}: v5 peers must pin the JSON line wire");
        assert_eq!(c.binary_connections, 0, "{kind:?}: nothing in this pool negotiated v6");
        assert_eq!(
            c.corrupt_frames_detected, 0,
            "{kind:?}: the pinned JSON stream must never read as corrupt"
        );
        assert_eq!(c.respawns, 0, "{kind:?}: no connection may have died");
    }
    // and the same build's stock workers negotiate v6 on every admit
    let stock = spawn(TransportKind::Tcp, 2, 1);
    let c = stock.run_counters();
    assert_eq!(c.binary_connections, 2, "stock workers must negotiate the binary wire");
    assert_eq!(c.json_connections, 0);
}

/// A pool whose driver-side chaos corrupts EVERY sent frame: each
/// attempt's first post-handshake frame is mangled, the worker's checksum
/// verify kills the connection, and the task can never complete over the
/// wire — the deterministic way to exhaust [`MAX_TASK_ATTEMPTS`].
fn always_corrupting_pool(on_exhausted: OnExhausted) -> Arc<ClusterBackend> {
    Arc::new(
        ClusterBackend::with_options(
            env!("CARGO_BIN_EXE_parccm"),
            ClusterOptions {
                transport: TransportKind::Pipe,
                workers: 1,
                replicas: 1,
                on_exhausted,
                chaos: Some((11, ChaosProfile::parse("corrupt_send=1").expect("profile"))),
                ..ClusterOptions::default()
            },
        )
        .expect("the handshake is chaos-exempt, so the spawn must succeed"),
    )
}

#[test]
fn exhausted_task_aborts_with_a_typed_actionable_message() {
    let _guard = Watchdog::arm("exhausted_abort", TEST_TIMEOUT);
    let pb = always_corrupting_pool(OnExhausted::Abort);
    let (x, y) = series(200);
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let samples = draw_samples(&Rng::new(17), CcmParams::new(2, 1, 60), problem.emb.n, 1);
    let input = problem.input_for(&samples[0]);
    let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut arena = TaskArena::new();
        pb.cross_map_into(&input, &mut arena)
    }))
    .expect_err("every attempt is corrupted, so the default policy must abort");
    let msg = panicked
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| panicked.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("attempts"), "must say the retries were spent: {msg}");
    assert!(
        msg.contains("--on-exhausted fallback"),
        "must point at the degradation knob: {msg}"
    );
    assert_eq!(pb.run_counters().exhausted_fallbacks, 0, "abort must not silently fall back");
}

#[test]
fn exhausted_task_falls_back_to_native_bit_identically() {
    let _guard = Watchdog::arm("exhausted_fallback", TEST_TIMEOUT);
    let pb = always_corrupting_pool(OnExhausted::Fallback);
    let (x, y) = series(200);
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let samples = draw_samples(&Rng::new(17), CcmParams::new(2, 1, 60), problem.emb.n, 2);
    let mut arena_p = TaskArena::new();
    let mut arena_n = TaskArena::new();
    for s in &samples {
        let input = problem.input_for(s);
        let rho = pb.cross_map_into(&input, &mut arena_p);
        let want = NativeBackend.cross_map_into(&input, &mut arena_n);
        assert_eq!(
            rho.to_bits(),
            want.to_bits(),
            "the in-process fallback must be bit-identical to native"
        );
        assert_eq!(arena_p.preds, arena_n.preds);
    }
    assert!(
        pb.run_counters().exhausted_fallbacks >= 1,
        "every task exhausts its attempts here, so the fallback must be counted"
    );
    assert!(
        pb.run_counters().respawns >= 1,
        "each corrupted attempt kills and respawns the worker"
    );
}

#[test]
fn manual_eviction_releases_and_reships_on_reuse() {
    let _guard = Watchdog::arm("manual_eviction", TEST_TIMEOUT);
    let pb = spawn(TransportKind::Pipe, 2, 1);
    let (x, y) = series(250);
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let samples = draw_samples(&Rng::new(9), CcmParams::new(2, 1, 70), problem.emb.n, 1);
    let input = problem.input_for(&samples[0]);
    let native = NativeBackend;
    let mut arena_p = TaskArena::new();
    let mut arena_n = TaskArena::new();
    let want = native.cross_map_into(&input, &mut arena_n);

    assert_eq!(pb.cross_map_into(&input, &mut arena_p).to_bits(), want.to_bits());
    assert_eq!(pb.cached_payloads(), 1);
    let ships_before = pb.run_counters().broadcast_ships;

    let pid = problem_wire_id(&problem.emb.vecs, &problem.targets, &problem.times);
    pb.evict_broadcast_ids(&[pid]);
    assert_eq!(pb.cached_payloads(), 0);
    assert!(pb.run_counters().evictions >= 1, "the idle holder must be told to drop its copy");

    // reuse after eviction: payload is rebuilt and re-shipped, results
    // stay exact (content addressing makes this safe by construction)
    assert_eq!(pb.cross_map_into(&input, &mut arena_p).to_bits(), want.to_bits());
    assert!(
        pb.run_counters().broadcast_ships > ships_before,
        "evicted broadcast must re-ship on reuse"
    );
    assert_eq!(pb.run_counters().respawns, 0);
}

#[test]
fn driver_run_evicts_broadcasts_on_both_transports() {
    // an A2 (brute-force, every task over the wire) run through the
    // driver: skills bit-identical to native, and by the end the payload
    // cache is empty because the driver evicted each harvested problem.
    let _guard = Watchdog::arm("driver_run_evicts", TEST_TIMEOUT);
    let scenario = Scenario::smoke();
    let (x, y) = series(scenario.series_len);
    let deploy = Deploy::Local { cores: 2 };
    let reference = RunSpec::new(Case::A2, &scenario, &y, &x)
        .deploy(deploy.clone())
        .run(Arc::new(NativeBackend));
    let key = |r: &parccm::ccm::result::SkillRow| {
        (r.params.e, r.params.tau, r.params.l, r.sample_id)
    };
    let mut want = reference.skills;
    want.sort_by_key(key);
    for kind in [TransportKind::Pipe, TransportKind::Tcp] {
        let pb = spawn(kind, 2, 1);
        let backend: Arc<dyn ComputeBackend> = pb.clone();
        let rep = RunSpec::new(Case::A2, &scenario, &y, &x).deploy(deploy.clone()).run(backend);
        let mut got = rep.skills;
        got.sort_by_key(key);
        assert_eq!(got.len(), want.len());
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(key(w), key(g));
            assert_eq!(w.rho.to_bits(), g.rho.to_bits(), "{kind:?} must match native bitwise");
        }
        assert_eq!(pb.cached_payloads(), 0, "{kind:?}: payloads evicted after harvest");
        assert!(pb.run_counters().evictions > 0, "{kind:?}: workers told to evict");
    }
}

#[test]
fn worker_reduce_over_workers_matches_driver_reduce_and_cuts_ingress() {
    // the tentpole acceptance, in-tree: the same sharded A4 case through
    // real worker processes under BOTH reduce placements. Worker-side
    // reduce must (a) agree with the in-process worker-reduce run
    // bit-for-bit (the v5 sums frames round-trip f64 exactly), (b) stay
    // within 1 ULP of the driver-concat skills, and (c) pull >= 5x fewer
    // result bytes into the driver — six f64 sums per (skill, shard)
    // instead of every prediction row.
    let _guard = Watchdog::arm("worker_reduce_over_workers", TEST_TIMEOUT);
    // a longer series than smoke: the ingress ratio scales with rows per
    // shard (driver-reduce ships 4 bytes per prediction row on the v6
    // binary wire, worker reduce a fixed six-sum record per task), so at
    // n ~ 800 the >= 5x bound holds with a wide margin instead of
    // sitting on the boundary
    let mut scenario = Scenario::smoke();
    scenario.series_len = 800;
    scenario.ls = vec![200];
    scenario.r = 6;
    let (x, y) = series(scenario.series_len);
    let deploy = Deploy::Local { cores: 2 };
    let spec = |reduce: ReduceMode| {
        RunSpec::new(Case::A4, &scenario, &y, &x)
            .deploy(deploy.clone())
            .policy(TablePolicy::TruncatedAuto)
            .shards(3)
            .reduce(reduce)
    };
    let key = |r: &parccm::ccm::result::SkillRow| {
        (r.params.e, r.params.tau, r.params.l, r.sample_id)
    };
    let sort = |mut rows: Vec<parccm::ccm::result::SkillRow>| {
        rows.sort_by_key(key);
        rows
    };
    let local_worker_red = sort(spec(ReduceMode::Worker).run(Arc::new(NativeBackend)).skills);

    let driver_pool = spawn(TransportKind::Tcp, 2, 1);
    let driver_red =
        sort(spec(ReduceMode::Driver).run(driver_pool.clone() as Arc<dyn ComputeBackend>).skills);
    let driver_ingress = driver_pool.run_counters().result_ingress_bytes;

    let worker_pool = spawn(TransportKind::Tcp, 2, 1);
    let worker_red =
        sort(spec(ReduceMode::Worker).run(worker_pool.clone() as Arc<dyn ComputeBackend>).skills);
    let worker_ingress = worker_pool.run_counters().result_ingress_bytes;

    assert_eq!(worker_red.len(), driver_red.len());
    assert_eq!(worker_red.len(), local_worker_red.len());
    for ((w, d), l) in worker_red.iter().zip(&driver_red).zip(&local_worker_red) {
        assert_eq!(key(w), key(d));
        assert_eq!(
            w.rho.to_bits(),
            l.rho.to_bits(),
            "wire worker-reduce must be bit-identical to in-process worker-reduce at {:?}",
            key(w)
        );
        assert!(
            f32_ulp_distance(w.rho, d.rho) <= 1,
            "worker-reduce rho {} drifts > 1 ULP from driver-concat {} at {:?}",
            w.rho,
            d.rho,
            key(w)
        );
    }
    assert!(worker_ingress > 0, "accepted result frames must be counted");
    assert!(
        driver_ingress >= 5 * worker_ingress,
        "worker-side reduce must cut result ingress >= 5x (driver {driver_ingress} vs \
         worker {worker_ingress})"
    );
}

#[test]
fn corrupted_agg_frame_requeues_without_double_counting() {
    // chaos on the shuffle stage: exactly one driver-received frame is
    // corrupted mid-run (an agg_chunk/merge_sums reply under worker-side
    // reduce), the connection dies on the checksum, and the lost partial
    // is recomputed on the respawned worker. combine_shard_sums panics on
    // any duplicate shard partial and on partial coverage, so agreeing
    // with the clean in-process run proves the requeue neither dropped
    // nor double-counted a partial sum.
    let _guard = Watchdog::arm("corrupted_agg_frame", TEST_TIMEOUT);
    let scenario = Scenario::smoke();
    let (x, y) = series(scenario.series_len);
    let deploy = Deploy::Local { cores: 2 };
    let spec = || {
        RunSpec::new(Case::A4, &scenario, &y, &x)
            .deploy(deploy.clone())
            .policy(TablePolicy::TruncatedAuto)
            .shards(3)
            .reduce(ReduceMode::Worker)
    };
    let key = |r: &parccm::ccm::result::SkillRow| {
        (r.params.e, r.params.tau, r.params.l, r.sample_id)
    };
    let mut want = spec().run(Arc::new(NativeBackend)).skills;
    want.sort_by_key(key);

    let pb = Arc::new(
        ClusterBackend::with_options(
            env!("CARGO_BIN_EXE_parccm"),
            ClusterOptions {
                transport: TransportKind::Tcp,
                workers: 2,
                replicas: 1,
                chaos: Some((23, ChaosProfile::parse("corrupt_once=12").expect("profile"))),
                ..ClusterOptions::default()
            },
        )
        .expect("the handshake is chaos-exempt, so the spawn must succeed"),
    );
    let mut got = spec().run(pb.clone() as Arc<dyn ComputeBackend>).skills;
    got.sort_by_key(key);

    assert_eq!(got.len(), want.len());
    for (w, g) in want.iter().zip(&got) {
        assert_eq!(key(w), key(g));
        assert_eq!(
            w.rho.to_bits(),
            g.rho.to_bits(),
            "requeued partial must reproduce the clean run exactly at {:?}",
            key(w)
        );
    }
    let c = pb.run_counters();
    assert_eq!(c.corrupt_frames_detected, 1, "exactly one frame was scheduled to corrupt");
    assert!(c.respawns >= 1, "the corrupted connection must have been recycled");
}
