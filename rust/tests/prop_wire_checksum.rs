//! Property tests for the v4 wire checksum (`ccm::transport`):
//!
//! 1. any JSON frame round-trips `append_checksum` -> `verify_frame`
//!    bit-exactly, and
//! 2. flipping any single byte of a checksummed frame is *always*
//!    detected — by the checksum, by UTF-8 validation, or (when the flip
//!    lands on `\n`) by the shorn partial frame failing verification.
//!
//! Detection must hold for every byte position, so each case exhaustively
//! sweeps the whole frame rather than sampling positions.

use parccm::ccm::transport::{append_checksum, frame_checksum, verify_frame, FRAME_CHECKSUM_LEN};
use parccm::util::json::Json;
use parccm::util::prop::check;
use parccm::util::rng::Rng;

/// A random JSON value shaped like real wire traffic: nested objects and
/// arrays of numbers/strings, including the exotic corners the cluster
/// protocol actually ships (full-precision f64s, escapes, empty strings).
fn arbitrary_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => {
            // mix of integers, subnormal-ish values, and raw f64 bit noise
            let x = match rng.below(3) {
                0 => rng.below(1_000_000) as f64,
                1 => rng.f64() * 1e-30,
                _ => rng.f64() * 1e12 - 5e11,
            };
            Json::Num(x)
        }
        3 => {
            let len = rng.below(20);
            let s: String = (0..len)
                .map(|_| {
                    // printable ASCII plus the JSON-escape troublemakers
                    match rng.below(8) {
                        0 => '"',
                        1 => '\\',
                        2 => '\u{7f}',
                        _ => (0x20 + rng.below(0x5f) as u8) as char,
                    }
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr((0..rng.below(5)).map(|_| arbitrary_json(rng, depth - 1)).collect()),
        _ => {
            let n = rng.below(4);
            Json::obj(
                (0..n)
                    .map(|i| match i {
                        0 => ("type", arbitrary_json(rng, depth - 1)),
                        1 => ("id", arbitrary_json(rng, depth - 1)),
                        2 => ("rows", arbitrary_json(rng, depth - 1)),
                        _ => ("payload", arbitrary_json(rng, depth - 1)),
                    })
                    .collect(),
            )
        }
    }
}

#[test]
fn checksummed_frames_round_trip_bit_exactly() {
    check("v4 frame round-trip", 300, |rng| {
        let payload = arbitrary_json(rng, 3).to_string();
        let frame = append_checksum(&payload);
        if frame.len() != payload.len() + FRAME_CHECKSUM_LEN {
            return Err(format!(
                "suffix must be exactly {FRAME_CHECKSUM_LEN} bytes, frame {frame:?}"
            ));
        }
        match verify_frame(&frame) {
            Ok(body) if body == payload => Ok(()),
            Ok(body) => Err(format!("round-trip mangled the body: {payload:?} -> {body:?}")),
            Err(e) => Err(format!("fresh frame failed verification: {e}")),
        }
    });
}

#[test]
fn trailing_newlines_are_framing_not_payload() {
    check("CRLF tolerance", 100, |rng| {
        let payload = arbitrary_json(rng, 2).to_string();
        let frame = append_checksum(&payload);
        for suffix in ["\n", "\r\n"] {
            match verify_frame(&format!("{frame}{suffix}")) {
                Ok(body) if body == payload => {}
                other => return Err(format!("with {suffix:?} terminator: {other:?}")),
            }
        }
        Ok(())
    });
}

/// What the receiving end sees after one byte of the frame is flipped in
/// flight. A flip can leave the bytes unreadable as UTF-8 (the transport
/// rejects the line before verification), or turn a byte into `\n` (the
/// line reader shears the frame at the flip); both count as detected only
/// if the surviving prefix *also* fails verification.
fn flip_is_detected(frame: &str, pos: usize, flip: u8) -> Result<(), String> {
    let mut bytes = frame.as_bytes().to_vec();
    bytes[pos] ^= flip;
    if bytes[pos] == b'\n' {
        // the line reader would deliver only the prefix as a frame
        bytes.truncate(pos);
    }
    let Ok(corrupted) = std::str::from_utf8(&bytes) else {
        return Ok(()); // rejected before verification: detected
    };
    match verify_frame(corrupted) {
        Err(_) => Ok(()),
        Ok(body) => Err(format!(
            "flip of byte {pos} (xor {flip:#04x}) in {frame:?} passed verification \
             with body {body:?}"
        )),
    }
}

#[test]
fn every_single_byte_flip_is_detected() {
    check("single-byte corruption detection", 120, |rng| {
        let payload = arbitrary_json(rng, 3).to_string();
        let frame = append_checksum(&payload);
        // one random non-zero flip pattern per case, applied at EVERY
        // position — body bytes, the '#' separator, and all 16 hex digits
        let flip = 1 + rng.below(0xfe) as u8;
        for pos in 0..frame.len() {
            flip_is_detected(&frame, pos, flip)?;
        }
        Ok(())
    });
}

#[test]
fn checksum_is_order_sensitive() {
    // FNV-1a is byte-order sensitive: transposed payloads must not
    // collide (a plain XOR/ADD checksum would pass this pair).
    let a = frame_checksum(br#"{"id":12,"rows":34}"#);
    let b = frame_checksum(br#"{"id":34,"rows":12}"#);
    assert_ne!(a, b);
    assert_eq!(frame_checksum(b""), 0xcbf29ce484222325, "FNV-1a offset basis");
}
