//! Property tests for the v4 wire checksum (`ccm::transport`) and the v6
//! binary codec (`ccm::binwire`):
//!
//! 1. any JSON frame round-trips `append_checksum` -> `verify_frame`
//!    bit-exactly, and
//! 2. flipping any single byte of a checksummed frame is *always*
//!    detected — by the checksum, by UTF-8 validation, or (when the flip
//!    lands on `\n`) by the shorn partial frame failing verification;
//! 3. every v6 binary message type round-trips encode -> decode
//!    bit-exactly, including NaN, ±0.0, infinities, and raw f32/f64 bit
//!    noise (the wire carries raw little-endian bytes, so nothing is
//!    canonicalized); and
//! 4. flipping any single byte of a checksummed *binary* frame is always
//!    rejected by `verify_binary_frame` — binary framing is
//!    length-prefixed, so there is no newline-shear escape hatch: every
//!    corrupted byte reaches the checksum and must be caught there.
//!
//! Detection must hold for every byte position, so each case exhaustively
//! sweeps the whole frame rather than sampling positions.

use parccm::ccm::binwire::{self, BinMsg, Broadcast};
use parccm::ccm::embedding::Embedding;
use parccm::ccm::pipeline::PearsonSums;
use parccm::ccm::table::DistanceTable;
use parccm::ccm::transport::{
    append_checksum, append_frame_checksum, frame_checksum, verify_binary_frame, verify_frame,
    FRAME_BIN_CHECKSUM_LEN, FRAME_CHECKSUM_LEN,
};
use parccm::util::json::Json;
use parccm::util::prop::check;
use parccm::util::rng::Rng;

/// A random JSON value shaped like real wire traffic: nested objects and
/// arrays of numbers/strings, including the exotic corners the cluster
/// protocol actually ships (full-precision f64s, escapes, empty strings).
fn arbitrary_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => {
            // mix of integers, subnormal-ish values, and raw f64 bit noise
            let x = match rng.below(3) {
                0 => rng.below(1_000_000) as f64,
                1 => rng.f64() * 1e-30,
                _ => rng.f64() * 1e12 - 5e11,
            };
            Json::Num(x)
        }
        3 => {
            let len = rng.below(20);
            let s: String = (0..len)
                .map(|_| {
                    // printable ASCII plus the JSON-escape troublemakers
                    match rng.below(8) {
                        0 => '"',
                        1 => '\\',
                        2 => '\u{7f}',
                        _ => (0x20 + rng.below(0x5f) as u8) as char,
                    }
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr((0..rng.below(5)).map(|_| arbitrary_json(rng, depth - 1)).collect()),
        _ => {
            let n = rng.below(4);
            Json::obj(
                (0..n)
                    .map(|i| match i {
                        0 => ("type", arbitrary_json(rng, depth - 1)),
                        1 => ("id", arbitrary_json(rng, depth - 1)),
                        2 => ("rows", arbitrary_json(rng, depth - 1)),
                        _ => ("payload", arbitrary_json(rng, depth - 1)),
                    })
                    .collect(),
            )
        }
    }
}

#[test]
fn checksummed_frames_round_trip_bit_exactly() {
    check("v4 frame round-trip", 300, |rng| {
        let payload = arbitrary_json(rng, 3).to_string();
        let frame = append_checksum(&payload);
        if frame.len() != payload.len() + FRAME_CHECKSUM_LEN {
            return Err(format!(
                "suffix must be exactly {FRAME_CHECKSUM_LEN} bytes, frame {frame:?}"
            ));
        }
        match verify_frame(&frame) {
            Ok(body) if body == payload => Ok(()),
            Ok(body) => Err(format!("round-trip mangled the body: {payload:?} -> {body:?}")),
            Err(e) => Err(format!("fresh frame failed verification: {e}")),
        }
    });
}

#[test]
fn trailing_newlines_are_framing_not_payload() {
    check("CRLF tolerance", 100, |rng| {
        let payload = arbitrary_json(rng, 2).to_string();
        let frame = append_checksum(&payload);
        for suffix in ["\n", "\r\n"] {
            match verify_frame(&format!("{frame}{suffix}")) {
                Ok(body) if body == payload => {}
                other => return Err(format!("with {suffix:?} terminator: {other:?}")),
            }
        }
        Ok(())
    });
}

/// What the receiving end sees after one byte of the frame is flipped in
/// flight. A flip can leave the bytes unreadable as UTF-8 (the transport
/// rejects the line before verification), or turn a byte into `\n` (the
/// line reader shears the frame at the flip); both count as detected only
/// if the surviving prefix *also* fails verification.
fn flip_is_detected(frame: &str, pos: usize, flip: u8) -> Result<(), String> {
    let mut bytes = frame.as_bytes().to_vec();
    bytes[pos] ^= flip;
    if bytes[pos] == b'\n' {
        // the line reader would deliver only the prefix as a frame
        bytes.truncate(pos);
    }
    let Ok(corrupted) = std::str::from_utf8(&bytes) else {
        return Ok(()); // rejected before verification: detected
    };
    match verify_frame(corrupted) {
        Err(_) => Ok(()),
        Ok(body) => Err(format!(
            "flip of byte {pos} (xor {flip:#04x}) in {frame:?} passed verification \
             with body {body:?}"
        )),
    }
}

#[test]
fn every_single_byte_flip_is_detected() {
    check("single-byte corruption detection", 120, |rng| {
        let payload = arbitrary_json(rng, 3).to_string();
        let frame = append_checksum(&payload);
        // one random non-zero flip pattern per case, applied at EVERY
        // position — body bytes, the '#' separator, and all 16 hex digits
        let flip = 1 + rng.below(0xfe) as u8;
        for pos in 0..frame.len() {
            flip_is_detected(&frame, pos, flip)?;
        }
        Ok(())
    });
}

// ---- v6 binary codec -----------------------------------------------------

/// f32s shaped like hostile wire traffic: the named special values plus
/// raw bit noise (covers signaling NaNs and subnormals).
fn raw_f32s(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len)
        .map(|_| match rng.below(8) {
            0 => f32::NAN,
            1 => 0.0,
            2 => -0.0,
            3 => f32::INFINITY,
            4 => f32::NEG_INFINITY,
            _ => f32::from_bits(rng.next_u64() as u32),
        })
        .collect()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn binary_problem_and_result_frames_round_trip_bit_exactly() {
    check("v6 codec round-trip", 200, |rng| {
        let id = rng.next_u64();
        let vecs = raw_f32s(rng, rng.below(64));
        let targets = raw_f32s(rng, rng.below(64));
        let times = raw_f32s(rng, rng.below(64));
        match binwire::decode(&binwire::encode_problem(id, &vecs, &targets, &times))
            .map_err(|e| format!("problem frame: {e}"))?
        {
            BinMsg::Broadcast(Broadcast::Problem { id: ri, vecs: rv, targets: rt, times: rm }) => {
                if ri != id
                    || bits(&rv) != bits(&vecs)
                    || bits(&rt) != bits(&targets)
                    || bits(&rm) != bits(&times)
                {
                    return Err("problem frame mangled a section".into());
                }
            }
            _ => return Err("problem frame decoded to the wrong variant".into()),
        }
        match binwire::decode(&binwire::encode_targets(id, &targets))
            .map_err(|e| format!("targets frame: {e}"))?
        {
            BinMsg::Broadcast(Broadcast::Targets { id: ri, targets: rt }) => {
                if ri != id || bits(&rt) != bits(&targets) {
                    return Err("targets frame mangled a section".into());
                }
            }
            _ => return Err("targets frame decoded to the wrong variant".into()),
        }
        let task = rng.next_u64() >> rng.below(48);
        let rho = match rng.below(3) {
            0 => None,
            1 => Some(f32::NAN),
            _ => Some(f32::from_bits(rng.next_u64() as u32)),
        };
        match binwire::decode(&binwire::encode_result_preds(task, rho, &vecs))
            .map_err(|e| format!("preds frame: {e}"))?
        {
            BinMsg::ResultPreds { task: rt, rho: rr, preds: rp } => {
                if rt != task
                    || rr.map(f32::to_bits) != rho.map(f32::to_bits)
                    || bits(&rp) != bits(&vecs)
                {
                    return Err("preds frame mangled a section".into());
                }
            }
            _ => return Err("preds frame decoded to the wrong variant".into()),
        }
        let sums = PearsonSums {
            n: rng.next_u64() >> 12,
            sx: f64::from_bits(rng.next_u64()),
            sy: f64::from_bits(rng.next_u64()),
            sxy: f64::from_bits(rng.next_u64()),
            sxx: f64::from_bits(rng.next_u64()),
            syy: f64::from_bits(rng.next_u64()),
        };
        match binwire::decode(&binwire::encode_result_sums(task, &sums))
            .map_err(|e| format!("sums frame: {e}"))?
        {
            BinMsg::ResultSums { task: rt, sums: rs } => {
                let same = rt == task
                    && rs.n == sums.n
                    && [rs.sx, rs.sy, rs.sxy, rs.sxx, rs.syy]
                        .iter()
                        .zip([sums.sx, sums.sy, sums.sxy, sums.sxx, sums.syy].iter())
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                if !same {
                    return Err("sums frame mangled a section".into());
                }
            }
            _ => return Err("sums frame decoded to the wrong variant".into()),
        }
        // control messages survive the TAG_JSON envelope verbatim
        let line = arbitrary_json(rng, 2).to_string();
        match binwire::decode(&binwire::encode_json(&line))
            .map_err(|e| format!("json envelope: {e}"))?
        {
            BinMsg::Json(m) if m.to_string() == Json::parse(&line).unwrap().to_string() => Ok(()),
            _ => Err("json envelope mangled the line".into()),
        }
    });
}

#[test]
fn binary_shard_frames_round_trip_bit_exactly() {
    check("v6 shard round-trip", 12, |rng| {
        let n = 40 + rng.below(80);
        let series: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let emb = Embedding::new(&series, 2, 1);
        let prefix = 4 + rng.below(12);
        let table = DistanceTable::build_truncated(&emb, prefix);
        let sharded = table.shard(1 + rng.below(4));
        for shard in sharded.shards() {
            let frame = binwire::encode_shard(shard.wire_id(), shard);
            match binwire::decode(&frame).map_err(|e| format!("shard frame: {e}"))? {
                BinMsg::Broadcast(Broadcast::Shard { id, shard: back }) => {
                    let (n0, v0) = shard.raw_parts();
                    let (n1, v1) = back.raw_parts();
                    let same = id == shard.wire_id()
                        && back.wire_id() == shard.wire_id()
                        && (back.shard_id, back.row_lo, back.row_hi, back.n, back.t0)
                            == (shard.shard_id, shard.row_lo, shard.row_hi, shard.n, shard.t0)
                        && n1 == n0
                        && bits(v1) == bits(v0);
                    if !same {
                        return Err("shard frame mangled a section".into());
                    }
                }
                _ => return Err("shard frame decoded to the wrong variant".into()),
            }
        }
        Ok(())
    });
}

#[test]
fn every_binary_frame_byte_flip_is_detected() {
    check("binary single-byte corruption detection", 60, |rng| {
        let body: Vec<u8> = (0..1 + rng.below(120)).map(|_| rng.next_u64() as u8).collect();
        let frame = append_frame_checksum(&body);
        if frame.len() != body.len() + FRAME_BIN_CHECKSUM_LEN {
            return Err(format!(
                "trailer must be exactly {FRAME_BIN_CHECKSUM_LEN} bytes, got frame of {}",
                frame.len()
            ));
        }
        match verify_binary_frame(&frame) {
            Ok(b) if b == &body[..] => {}
            Ok(_) => return Err("round-trip mangled the body".into()),
            Err(e) => return Err(format!("fresh frame failed verification: {e}")),
        }
        // one random non-zero flip pattern, applied at EVERY position —
        // body bytes and all 8 trailer bytes alike
        let flip = 1 + rng.below(0xfe) as u8;
        for pos in 0..frame.len() {
            let mut corrupted = frame.clone();
            corrupted[pos] ^= flip;
            if verify_binary_frame(&corrupted).is_ok() {
                return Err(format!(
                    "flip of byte {pos} (xor {flip:#04x}) in a {}-byte frame passed verification",
                    frame.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn checksum_is_order_sensitive() {
    // FNV-1a is byte-order sensitive: transposed payloads must not
    // collide (a plain XOR/ADD checksum would pass this pair).
    let a = frame_checksum(br#"{"id":12,"rows":34}"#);
    let b = frame_checksum(br#"{"id":34,"rows":12}"#);
    assert_ne!(a, b);
    assert_eq!(frame_checksum(b""), 0xcbf29ce484222325, "FNV-1a offset basis");
}
