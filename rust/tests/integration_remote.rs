//! End-to-end tests of the `--workers-at` remote worker mode: the driver
//! connects to pre-started `parccm worker --listen` processes (spawned by
//! the test itself, like the `cluster-remote` CI job does with
//! `scripts/launch_local_cluster.sh`) instead of forking children.
//! Covered here: bit-identical results through real remote workers with a
//! mid-run kill, the authenticated handshake failing cleanly on BOTH ends,
//! keepalive detection of a silently-dead worker, and the actionable abort
//! when the last remote worker is gone (no respawn possible). Every test
//! arms a [`Watchdog`] so a hung socket fails CI fast.
//!
//! This file is also the deterministic cluster fault-injection harness
//! for reconnect/rejoin (`--rejoin-backoff-secs`): kill/restart schedules
//! are driven by a seeded [`Rng`], every wait is an *observable sync
//! point* (a counter poll with a deadline — `remote_lost`, `rejoins`,
//! `keepalive_deaths`, `rejoin_rejected` — never a bare sleep standing in
//! for cluster state), fault *kinds* ride on env seams
//! ([`TEST_IGNORE_PING_ENV`] plays silently dead; a restart with a wrong
//! [`AUTH_TOKEN_ENV`] plays misconfigured), and the canonical
//! `skills_to_json` dump is asserted byte-identical after every fault
//! schedule.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use parccm::ccm::backend::{ComputeBackend, TaskArena};
use parccm::ccm::chaos::ChaosProfile;
use parccm::ccm::cluster::{ClusterBackend, ClusterOptions, TEST_HELLO_V_ENV, TEST_IGNORE_PING_ENV};
use parccm::ccm::driver::{skills_to_json, Case, RunSpec, TablePolicy};
use parccm::ccm::params::{CcmParams, Scenario};
use parccm::ccm::pipeline::CcmProblem;
use parccm::ccm::subsample::draw_samples;
use parccm::ccm::transport::AUTH_TOKEN_ENV;
use parccm::engine::Deploy;
use parccm::native::NativeBackend;
use parccm::util::rng::Rng;
use parccm::util::watchdog::Watchdog;

const TEST_TIMEOUT: Duration = Duration::from_secs(180);

fn series(n: usize) -> (Vec<f32>, Vec<f32>) {
    parccm::timeseries::generators::coupled_logistic(
        n,
        parccm::timeseries::generators::CoupledLogisticParams::default(),
    )
}

fn kill9(pid: u32) {
    let status = std::process::Command::new("kill")
        .args(["-9", &pid.to_string()])
        .status()
        .expect("running kill");
    assert!(status.success(), "kill -9 {pid}");
}

/// Wedge (not kill) a worker: SIGSTOP freezes the process but keeps its
/// sockets open, so the driver sees a healthy connection that simply
/// never answers — the straggler shape only a deadline/speculation
/// defense can recover from (the ListenWorker Drop's SIGKILL still
/// reaps a stopped process).
fn sigstop(pid: u32) {
    let status = std::process::Command::new("kill")
        .args(["-STOP", &pid.to_string()])
        .status()
        .expect("running kill");
    assert!(status.success(), "kill -STOP {pid}");
}

/// A pre-started listen-mode worker owned by the test; its ephemeral
/// address is parsed from the `PARCCM_WORKER_LISTENING` stdout line
/// (exactly what `scripts/launch_local_cluster.sh` does). Killed on drop.
struct ListenWorker {
    child: Option<Child>,
    addr: String,
}

impl ListenWorker {
    fn start(extra_env: &[(&str, &str)]) -> ListenWorker {
        Self::start_with(extra_env, false)
    }

    /// `capture_stderr` pipes the worker's stderr for later inspection
    /// via [`Self::wait_output`] (the auth tests assert its contents).
    fn start_with(extra_env: &[(&str, &str)], capture_stderr: bool) -> ListenWorker {
        Self::spawn_at("127.0.0.1:0", extra_env, capture_stderr)
            .expect("spawning listen worker")
    }

    /// Restart a listener on the exact address a previous worker died on
    /// — the rejoin shape. The worker binds with `SO_REUSEADDR`, but the
    /// spawn is still retried briefly in case the OS has not finished
    /// tearing the old socket down.
    fn restart_at(addr: &str, extra_env: &[(&str, &str)]) -> ListenWorker {
        Self::restart_at_with(addr, extra_env, false)
    }

    fn restart_at_with(
        addr: &str,
        extra_env: &[(&str, &str)],
        capture_stderr: bool,
    ) -> ListenWorker {
        let deadline = Instant::now() + Duration::from_secs(15);
        loop {
            match Self::spawn_at(addr, extra_env, capture_stderr) {
                Ok(w) => {
                    assert_eq!(w.addr, addr, "restarted worker must bind the recorded port");
                    return w;
                }
                Err(e) if Instant::now() < deadline => {
                    eprintln!("[test] re-listen on {addr} not ready yet ({e}); retrying");
                    std::thread::sleep(Duration::from_millis(100));
                }
                Err(e) => panic!("could not re-listen on {addr}: {e}"),
            }
        }
    }

    /// Spawn `parccm worker --listen ADDR` and wait for its ready line;
    /// `Err` when the worker exits before announcing (e.g. bind failure).
    fn spawn_at(
        addr: &str,
        extra_env: &[(&str, &str)],
        capture_stderr: bool,
    ) -> Result<ListenWorker, String> {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_parccm"));
        cmd.args(["worker", "--listen", addr]).stdout(Stdio::piped()).stderr(
            if capture_stderr {
                Stdio::piped()
            } else {
                Stdio::null()
            },
        );
        for (k, v) in extra_env {
            cmd.env(k, v);
        }
        let mut child = cmd.spawn().map_err(|e| format!("spawn failed: {e}"))?;
        let stdout = child.stdout.take().expect("piped stdout");
        let ready = match BufReader::new(stdout).lines().next() {
            Some(Ok(line)) => line,
            other => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(format!("worker exited before announcing its address: {other:?}"));
            }
        };
        let addr = ready
            .strip_prefix("PARCCM_WORKER_LISTENING ")
            .unwrap_or_else(|| panic!("unexpected ready line: {ready}"))
            .trim()
            .to_string();
        Ok(ListenWorker { child: Some(child), addr })
    }

    fn pid(&self) -> u32 {
        self.child.as_ref().expect("worker still owned").id()
    }

    /// Wait for the worker to exit on its own and collect its output
    /// (requires `start_with(_, true)` for a captured stderr).
    fn wait_output(mut self) -> std::process::Output {
        self.child
            .take()
            .expect("worker still owned")
            .wait_with_output()
            .expect("collecting worker output")
    }
}

impl Drop for ListenWorker {
    fn drop(&mut self) {
        if let Some(child) = self.child.as_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

fn remote_pool(addrs: Vec<String>, replicas: usize, keepalive: Option<Duration>) -> ClusterBackend {
    ClusterBackend::with_options(
        env!("CARGO_BIN_EXE_parccm"),
        ClusterOptions {
            replicas,
            workers_at: addrs,
            keepalive,
            ..ClusterOptions::default()
        },
    )
    .expect("connecting the remote worker pool")
}

/// Observable sync point for fault schedules: poll a pool counter until
/// it reports the expected state (bounded by a deadline), so the
/// schedule advances on *observed* cluster transitions, never on a sleep
/// that guesses at timing.
fn wait_for(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(60);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn remote_sharded_a4_bit_identical_with_midrun_kill() {
    // the acceptance scenario: a sharded A4 run through 3 pre-started
    // remote workers with --replicas 2, one worker killed mid-run — the
    // result must be bit-identical to the in-process reference (and hence
    // to the pipe backend, whose parity is pinned in integration_cluster).
    let _guard = Watchdog::arm("remote_sharded_a4", TEST_TIMEOUT);
    let workers = [
        ListenWorker::start(&[]),
        ListenWorker::start(&[]),
        ListenWorker::start(&[]),
    ];
    let scenario = Scenario::smoke();
    let (x, y) = series(scenario.series_len);
    let deploy = Deploy::Local { cores: 2 };

    let reference = RunSpec::new(Case::A4, &scenario, &y, &x)
        .deploy(deploy.clone())
        .policy(TablePolicy::TruncatedAuto)
        .shards(3)
        .run(Arc::new(NativeBackend));

    let remote = Arc::new(remote_pool(
        workers.iter().map(|w| w.addr.clone()).collect(),
        2,
        Some(Duration::from_millis(500)),
    ));
    assert!(remote.is_remote());
    assert_eq!(remote.num_workers(), 3, "pool width must equal the address list");
    assert_eq!(remote.replicas(), 2);

    let victim = workers[0].pid();
    let killer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(100));
        kill9(victim);
    });
    let backend: Arc<dyn ComputeBackend> = remote.clone();
    let via_remote = RunSpec::new(Case::A4, &scenario, &y, &x)
        .deploy(deploy)
        .policy(TablePolicy::TruncatedAuto)
        .shards(3)
        .run(backend);
    killer.join().unwrap();

    // bit-identical via the canonical dump (what the CI job diffs)
    assert_eq!(
        skills_to_json(&reference.skills).to_string(),
        skills_to_json(&via_remote.skills).to_string(),
        "remote sharded A4 must be bit-identical to the in-process run"
    );
    assert_eq!(via_remote.skills.len(), scenario.combos().len() * scenario.r);
    assert_eq!(remote.run_counters().respawns, 0, "remote workers are never respawned");
    assert!(remote.num_workers() >= 2, "at most the killed worker may be gone");
    assert_eq!(remote.cached_payloads(), 0, "harvested problems are evicted");
}

#[test]
fn mixed_version_pool_pins_json_per_connection_and_stays_bit_identical() {
    // the v6 rollout's mixed-fleet scenario: two current workers and one
    // stale v5 binary in the same pool. Negotiation is per connection —
    // the v5 worker's links stay on the checksummed JSON line wire while
    // the other two ship v6 binary frames — and the sharded A4 dump must
    // be byte-identical to a pure-JSON pool AND to the in-process
    // reference: the wire encoding can never leak into results.
    let _guard = Watchdog::arm("mixed_version_pool", TEST_TIMEOUT);
    let scenario = Scenario::smoke();
    let (x, y) = series(scenario.series_len);
    let reference = sharded_a4(&scenario, &y, &x, Arc::new(NativeBackend));

    // pure-JSON pool first: every worker doctored down to v5
    let json_workers: Vec<ListenWorker> =
        (0..3).map(|_| ListenWorker::start(&[(TEST_HELLO_V_ENV, "5")])).collect();
    let json_pool = Arc::new(ClusterBackend::with_options(
        env!("CARGO_BIN_EXE_parccm"),
        ClusterOptions {
            replicas: 2,
            workers_at: json_workers.iter().map(|w| w.addr.clone()).collect(),
            ..ClusterOptions::default()
        },
    )
    .expect("connecting the all-v5 pool"));
    let via_json = sharded_a4(&scenario, &y, &x, json_pool.clone());
    assert_eq!(via_json, reference, "all-v5 pool must match the in-process reference");
    let jc = json_pool.run_counters();
    assert_eq!(jc.json_connections, 3, "every v5 worker must pin the JSON line wire");
    assert_eq!(jc.binary_connections, 0);
    drop(json_workers);

    // mixed pool: 2 stock v6 workers + 1 doctored v5 straggler
    let mixed_workers = [
        ListenWorker::start(&[]),
        ListenWorker::start(&[]),
        ListenWorker::start(&[(TEST_HELLO_V_ENV, "5")]),
    ];
    let mixed = Arc::new(ClusterBackend::with_options(
        env!("CARGO_BIN_EXE_parccm"),
        ClusterOptions {
            replicas: 2,
            workers_at: mixed_workers.iter().map(|w| w.addr.clone()).collect(),
            ..ClusterOptions::default()
        },
    )
    .expect("connecting the mixed-version pool"));
    let via_mixed = sharded_a4(&scenario, &y, &x, mixed.clone());
    assert_eq!(via_mixed, via_json, "mixed pool must match the all-v5 dump byte for byte");
    assert_eq!(via_mixed, reference, "and the in-process reference");

    let mc = mixed.run_counters();
    assert_eq!(mc.binary_connections, 2, "the two stock workers must negotiate v6");
    assert_eq!(mc.json_connections, 1, "only the v5 worker's connection pins JSON");
    assert_eq!(mc.corrupt_frames_detected, 0, "both wires must verify cleanly");
    assert_eq!(mc.respawns, 0, "remote workers are never respawned");
    // a mixed fleet already moves fewer broadcast bytes than an all-JSON
    // one: with 3 shards x 2 replicas over 3 workers, at least 4 of the 6
    // shard ships ride the two binary links
    assert!(
        mc.broadcast_ship_bytes < jc.broadcast_ship_bytes,
        "mixed pool must ship fewer bytes than all-JSON ({} vs {})",
        mc.broadcast_ship_bytes,
        jc.broadcast_ship_bytes
    );
}

#[test]
fn wrong_auth_token_fails_cleanly_on_both_ends() {
    let _guard = Watchdog::arm("wrong_auth_token", Duration::from_secs(60));
    // a worker requiring the token "sesame", with stderr captured so the
    // worker-side error can be asserted too
    let worker = ListenWorker::start_with(&[(AUTH_TOKEN_ENV, "sesame")], true);

    // driver side: a clean named error, not a hang and not a panic
    let err = ClusterBackend::with_options(
        env!("CARGO_BIN_EXE_parccm"),
        ClusterOptions {
            workers_at: vec![worker.addr.clone()],
            auth_token: Some("wrong".to_string()),
            ..ClusterOptions::default()
        },
    )
    .expect_err("a mismatched token must refuse the pool");
    let msg = err.to_string();
    assert!(msg.contains("auth token mismatch"), "driver error must name auth: {msg}");
    assert!(!msg.contains("sesame") && !msg.contains("wrong"), "no token leak: {msg}");

    // worker side: the reject reaches it, it logs the named error and
    // exits non-zero
    let out = worker.wait_output();
    assert!(!out.status.success(), "rejected worker must exit with failure");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("rejected by driver") && stderr.contains("auth token mismatch"),
        "worker stderr must name the rejection: {stderr}"
    );
}

#[test]
fn tokenless_driver_is_refused_by_token_requiring_worker() {
    let _guard = Watchdog::arm("tokenless_driver", Duration::from_secs(60));
    let worker = ListenWorker::start(&[(AUTH_TOKEN_ENV, "sesame")]);
    let err = ClusterBackend::with_options(
        env!("CARGO_BIN_EXE_parccm"),
        ClusterOptions { workers_at: vec![worker.addr.clone()], ..ClusterOptions::default() },
    )
    .expect_err("a tokenless driver must be refused");
    let msg = err.to_string();
    assert!(msg.contains("auth token mismatch"), "{msg}");
    assert!(msg.contains("driver has none"), "must say which side lacks the token: {msg}");
}

#[test]
fn matching_auth_token_serves_tasks_bit_identically() {
    let _guard = Watchdog::arm("matching_auth_token", TEST_TIMEOUT);
    let worker = ListenWorker::start(&[(AUTH_TOKEN_ENV, "sesame")]);
    let pb = ClusterBackend::with_options(
        env!("CARGO_BIN_EXE_parccm"),
        ClusterOptions {
            workers_at: vec![worker.addr.clone()],
            auth_token: Some("sesame".to_string()),
            ..ClusterOptions::default()
        },
    )
    .expect("matching tokens must connect");
    let (x, y) = series(300);
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let samples = draw_samples(&Rng::new(21), CcmParams::new(2, 1, 90), problem.emb.n, 2);
    let mut arena_p = TaskArena::new();
    let mut arena_n = TaskArena::new();
    for s in &samples {
        let input = problem.input_for(s);
        let rho = pb.cross_map_into(&input, &mut arena_p);
        let want = NativeBackend.cross_map_into(&input, &mut arena_n);
        assert_eq!(rho.to_bits(), want.to_bits(), "authed remote must match native bitwise");
        assert_eq!(arena_p.preds, arena_n.preds);
    }
}

#[test]
fn keepalive_timeout_discards_silently_dead_worker() {
    // a worker that keeps its socket open but never answers pings must be
    // marked dead within the keepalive deadline — not on the next task —
    // and the pool must keep serving bit-identical results without it.
    let _guard = Watchdog::arm("keepalive_timeout", TEST_TIMEOUT);
    let good = ListenWorker::start(&[]);
    let deaf = ListenWorker::start(&[(TEST_IGNORE_PING_ENV, "1")]);
    let pb = remote_pool(
        vec![good.addr.clone(), deaf.addr.clone()],
        1,
        Some(Duration::from_millis(200)),
    );
    assert_eq!(pb.num_workers(), 2);

    let deadline = Instant::now() + Duration::from_secs(30);
    while pb.run_counters().keepalive_deaths == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(50));
    }
    assert_eq!(pb.run_counters().keepalive_deaths, 1, "the silent worker must be declared dead");
    assert_eq!(pb.run_counters().remote_lost, 1);
    assert_eq!(pb.num_workers(), 1, "only the responsive worker remains");

    // tasks requeue onto the survivor and stay exact
    let (x, y) = series(250);
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let samples = draw_samples(&Rng::new(5), CcmParams::new(2, 1, 70), problem.emb.n, 2);
    let mut arena_p = TaskArena::new();
    let mut arena_n = TaskArena::new();
    for s in &samples {
        let input = problem.input_for(s);
        let rho = pb.cross_map_into(&input, &mut arena_p);
        assert_eq!(rho.to_bits(), NativeBackend.cross_map_into(&input, &mut arena_n).to_bits());
    }
    assert_eq!(pb.run_counters().keepalive_deaths, 1, "the good worker must keep answering pings");
}

#[test]
fn last_remote_worker_death_aborts_with_actionable_message() {
    // --workers-at with one worker and --replicas 1: when it dies there is
    // nothing to requeue onto and nothing to respawn — the run must abort
    // with a message telling the operator what to do, not hang or loop.
    let _guard = Watchdog::arm("remote_pool_exhaustion", Duration::from_secs(60));
    let worker = ListenWorker::start(&[]);
    let pb = remote_pool(vec![worker.addr.clone()], 1, Some(Duration::ZERO));
    let (x, y) = series(250);
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let samples = draw_samples(&Rng::new(9), CcmParams::new(2, 1, 70), problem.emb.n, 1);
    let input = problem.input_for(&samples[0]);
    let mut arena = TaskArena::new();
    let healthy = pb.cross_map_into(&input, &mut arena);
    assert!(healthy.is_finite());

    kill9(worker.pid());
    std::thread::sleep(Duration::from_millis(200));

    let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pb.cross_map_into(&input, &mut arena)
    }))
    .expect_err("a dead remote pool must abort the task");
    let msg = panicked
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| panicked.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("cannot be respawned"), "actionable message, got: {msg}");
    assert!(msg.contains("--replicas"), "must point at the mitigation: {msg}");
    assert_eq!(pb.run_counters().remote_lost, 1);
    assert_eq!(pb.num_workers(), 0);
}

// ---------------------------------------------------------------------------
// reconnect/rejoin (--rejoin-backoff-secs) + the fault-injection harness
// ---------------------------------------------------------------------------

fn rejoin_pool(addrs: Vec<String>, replicas: usize) -> Arc<ClusterBackend> {
    Arc::new(
        ClusterBackend::with_options(
            env!("CARGO_BIN_EXE_parccm"),
            ClusterOptions {
                replicas,
                workers_at: addrs,
                keepalive: Some(Duration::from_millis(300)),
                rejoin_backoff: Some(Duration::from_millis(150)),
                ..ClusterOptions::default()
            },
        )
        .expect("connecting the remote worker pool"),
    )
}

fn sharded_a4(
    scenario: &Scenario,
    y: &[f32],
    x: &[f32],
    backend: Arc<dyn ComputeBackend>,
) -> String {
    let rep = RunSpec::new(Case::A4, scenario, y, x)
        .deploy(Deploy::Local { cores: 2 })
        .policy(TablePolicy::TruncatedAuto)
        .shards(3)
        .run(backend);
    skills_to_json(&rep.skills).to_string()
}

#[test]
fn killed_remote_worker_rejoins_and_serves_again() {
    // the acceptance schedule: sharded A4 over 3 remote workers, one
    // kill -9'd mid-grid; the listener is restarted on the SAME port and
    // the driver must redial it (rejoins >= 1), ship broadcasts to it on
    // demand (rejoin_ships >= 1 — tasks land on it again), and keep every
    // dump byte-identical to the in-process reference (and hence to the
    // pipe backend, whose parity is pinned in integration_cluster).
    let _guard = Watchdog::arm("rejoin_midgrid", TEST_TIMEOUT);
    let workers = [
        ListenWorker::start(&[]),
        ListenWorker::start(&[]),
        ListenWorker::start(&[]),
    ];
    let scenario = Scenario::smoke();
    let (x, y) = series(scenario.series_len);
    let reference = sharded_a4(&scenario, &y, &x, Arc::new(NativeBackend));

    let remote = rejoin_pool(workers.iter().map(|w| w.addr.clone()).collect(), 2);
    assert_eq!(remote.num_workers(), 3);

    // grid 1 with a mid-grid kill (the pool survives on replicas)
    let victim_pid = workers[0].pid();
    let victim_addr = workers[0].addr.clone();
    let killer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(100));
        kill9(victim_pid);
    });
    let first = sharded_a4(&scenario, &y, &x, remote.clone());
    killer.join().unwrap();
    assert_eq!(first, reference, "grid with a mid-grid kill must stay bit-identical");

    // sync point: the driver observed the death (mid-exchange or via the
    // keepalive prober while idle)
    wait_for("the death to be observed", || remote.run_counters().remote_lost >= 1);
    assert_eq!(remote.run_counters().rejoins, 0, "nothing to rejoin before the restart");

    // restart the listener on the recorded port; the redialer must
    // re-admit it with a fresh worker id and no duplicate pool entry
    let _revived = ListenWorker::restart_at(&victim_addr, &[]);
    wait_for("the rejoin", || remote.run_counters().rejoins >= 1);
    assert_eq!(remote.num_workers(), 3, "pool back at full width, exactly one entry");
    assert_eq!(remote.run_counters().rejoins, 1);

    // grid 2 through the recovered pool: the rejoined worker's empty
    // store re-populates on demand and results stay bit-identical. A kill
    // landing inside a sole-holder window DURING grid 1 may legitimately
    // force one re-broadcast (eager repair is best-effort while every
    // survivor is leased); what the rejoin guarantees is zero NEW
    // re-broadcasts after the repair window closed — pin exactly that.
    let rebroadcasts_after_recovery = remote.run_counters().rebroadcasts;
    let second = sharded_a4(&scenario, &y, &x, remote.clone());
    assert_eq!(second, reference, "post-rejoin grid must stay bit-identical");
    assert!(
        remote.run_counters().rejoin_ships >= 1,
        "tasks must land on the rejoined worker and re-ship its broadcasts on demand"
    );
    assert_eq!(
        remote.run_counters().rebroadcasts,
        rebroadcasts_after_recovery,
        "after the repair window + rejoin, nothing may force a full re-broadcast"
    );
    assert_eq!(
        remote.run_counters().respawns,
        0,
        "remote workers are never respawned, only rejoined"
    );
}

#[test]
fn seeded_chaos_schedule_stays_bit_identical() {
    // the deterministic chaos harness: a seeded RNG picks the victim each
    // round; every round is kill -> observe (sync point) -> restart ->
    // rejoin (sync point) -> full sharded grid -> byte-identical dump.
    let _guard = Watchdog::arm("chaos_schedule", Duration::from_secs(300));
    let mut workers: Vec<ListenWorker> =
        (0..3).map(|_| ListenWorker::start(&[])).collect();
    let scenario = Scenario::smoke();
    let (x, y) = series(scenario.series_len);
    let reference = sharded_a4(&scenario, &y, &x, Arc::new(NativeBackend));

    let remote = rejoin_pool(workers.iter().map(|w| w.addr.clone()).collect(), 2);
    let mut rng = Rng::new(0xC0FFEE);
    let rounds = 2u64;
    for round in 0..rounds {
        let victim = rng.below(workers.len());
        let addr = workers[victim].addr.clone();
        let lost_before = remote.run_counters().remote_lost;
        let rejoins_before = remote.run_counters().rejoins;
        kill9(workers[victim].pid());
        wait_for("the kill to be observed", || remote.run_counters().remote_lost > lost_before);
        workers[victim] = ListenWorker::restart_at(&addr, &[]);
        wait_for("the round's rejoin", || remote.run_counters().rejoins > rejoins_before);
        assert_eq!(remote.num_workers(), 3, "round {round}: full width, no duplicates");
        let got = sharded_a4(&scenario, &y, &x, remote.clone());
        assert_eq!(got, reference, "round {round}: dump must stay byte-identical");
    }
    assert_eq!(remote.run_counters().rejoins, rounds, "exactly one rejoin per round");
    assert_eq!(remote.run_counters().rebroadcasts, 0, "no fault schedule may force a re-broadcast");
}

#[test]
fn keepalive_discarded_worker_rejoins_without_duplicate_entries() {
    // keepalive/rejoin interaction: a silently-dead worker (socket open,
    // pings swallowed via the env seam) is discarded by the prober; its
    // process is then killed and a healthy listener restarted on the same
    // port — the pool must end with exactly one entry for that address
    // and replicas must not be double-counted.
    let _guard = Watchdog::arm("keepalive_then_rejoin", TEST_TIMEOUT);
    let good = ListenWorker::start(&[]);
    let deaf = ListenWorker::start(&[(TEST_IGNORE_PING_ENV, "1")]);
    let remote = ClusterBackend::with_options(
        env!("CARGO_BIN_EXE_parccm"),
        ClusterOptions {
            replicas: 2,
            workers_at: vec![good.addr.clone(), deaf.addr.clone()],
            keepalive: Some(Duration::from_millis(200)),
            rejoin_backoff: Some(Duration::from_millis(150)),
            ..ClusterOptions::default()
        },
    )
    .expect("connecting the remote worker pool");
    assert_eq!(remote.num_workers(), 2);

    // sync point 1: the prober declares the deaf worker dead. Its
    // process is still alive — rejoin redials against it are refused (it
    // closed its listener on accept) or time out on the short handshake
    // deadline; either way they must back off, not wedge the prober.
    wait_for("the keepalive discard", || remote.run_counters().keepalive_deaths >= 1);
    assert_eq!(remote.num_workers(), 1);

    let addr = deaf.addr.clone();
    kill9(deaf.pid());
    drop(deaf);
    let _revived = ListenWorker::restart_at(&addr, &[]);
    wait_for("the rejoin", || remote.run_counters().rejoins >= 1);
    assert_eq!(remote.num_workers(), 2, "exactly one pool entry for the rejoined address");
    assert_eq!(remote.run_counters().keepalive_deaths, 1);
    assert_eq!(remote.run_counters().remote_lost, 1);
    assert_eq!(remote.run_counters().rejoins, 1, "the same address must not rejoin twice");

    // replicas are not double-counted: one problem over a 2-worker pool
    // at factor 2 ships exactly twice (first ship + one replica copy),
    // with zero re-broadcasts — and results stay bitwise exact
    let (x, y) = series(250);
    let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
    let samples = draw_samples(&Rng::new(11), CcmParams::new(2, 1, 70), problem.emb.n, 2);
    let mut arena_p = TaskArena::new();
    let mut arena_n = TaskArena::new();
    for s in &samples {
        let input = problem.input_for(s);
        let rho = remote.cross_map_into(&input, &mut arena_p);
        assert_eq!(rho.to_bits(), NativeBackend.cross_map_into(&input, &mut arena_n).to_bits());
        assert_eq!(arena_p.preds, arena_n.preds);
    }
    // <= because eager replication is best-effort (a worker mid-probe is
    // not idle); > 2 would mean a phantom duplicate entry got a copy
    let ships = remote.run_counters().broadcast_ships;
    assert!((1..=2).contains(&ships), "factor 2 on 2 workers: no third copy ({ships})");
    assert_eq!(remote.run_counters().rebroadcasts, 0);
}

#[test]
fn seeded_chaos_with_wedged_worker_speculates_and_stays_bit_identical() {
    // the PR's acceptance scenario: a seeded chaos profile (frame delays
    // + exactly one corrupted frame) on every driver-side connection, one
    // worker SIGSTOPped before the grid — wedged, not dead: its sockets
    // stay open, so neither an exchange error nor the keepalive prober
    // (deliberately off here) can save its tasks. Only the lease scan's
    // speculative re-execution can, and the dump must STILL be
    // byte-identical to the in-process reference. No bare sleep gates any
    // assertion: the grid returning is itself the sync point (it cannot
    // complete unless speculation rescued the wedged worker's tasks), and
    // the counters are checked after that barrier.
    let _guard = Watchdog::arm("chaos_wedged_speculation", TEST_TIMEOUT);
    let workers = [
        ListenWorker::start(&[]),
        ListenWorker::start(&[]),
        ListenWorker::start(&[]),
    ];
    let scenario = Scenario::smoke();
    let (x, y) = series(scenario.series_len);
    let reference = sharded_a4(&scenario, &y, &x, Arc::new(NativeBackend));

    let remote = Arc::new(
        ClusterBackend::with_options(
            env!("CARGO_BIN_EXE_parccm"),
            ClusterOptions {
                replicas: 2,
                workers_at: workers.iter().map(|w| w.addr.clone()).collect(),
                // keepalive OFF: the wedged worker must be defeated by
                // speculation, not discarded by the prober
                keepalive: None,
                speculate_factor: Some(4.0),
                chaos: Some((
                    7,
                    ChaosProfile::parse("delay=6,delay_ms=2,corrupt_once=10")
                        .expect("chaos profile"),
                )),
                ..ClusterOptions::default()
            },
        )
        .expect("connecting the remote worker pool"),
    );
    assert_eq!(remote.num_workers(), 3);
    sigstop(workers[0].pid());

    let got = sharded_a4(&scenario, &y, &x, remote.clone());
    assert_eq!(got, reference, "chaos + wedge grid must stay bit-identical");

    assert!(
        remote.run_counters().speculative_launches >= 1,
        "the wedged worker's tasks can only finish via speculation \
         (launches {}, wins {})",
        remote.run_counters().speculative_launches,
        remote.run_counters().speculative_wins
    );
    assert!(
        remote.run_counters().speculative_wins >= 1,
        "a speculative duplicate must have beaten the wedged primary"
    );
    assert!(
        remote.run_counters().corrupt_frames_detected >= 1,
        "the corrupt_once frame must be caught by the v4 checksum, got {}",
        remote.run_counters().corrupt_frames_detected
    );
    assert_eq!(remote.run_counters().respawns, 0, "remote workers are never respawned");
    assert_eq!(remote.run_counters().deadline_kills, 0, "no deadline was configured");
}

#[test]
fn auth_mismatch_during_rejoin_permanently_rejects_the_address() {
    // the regression named by the issue: a listener that comes back
    // MISCONFIGURED (wrong token) must be retired after one rejected
    // handshake — named error on both ends, no hot redial loop.
    let _guard = Watchdog::arm("rejoin_auth_mismatch", TEST_TIMEOUT);
    let victim = ListenWorker::start(&[(AUTH_TOKEN_ENV, "sesame")]);
    let anchor = ListenWorker::start(&[(AUTH_TOKEN_ENV, "sesame")]);
    let remote = ClusterBackend::with_options(
        env!("CARGO_BIN_EXE_parccm"),
        ClusterOptions {
            workers_at: vec![victim.addr.clone(), anchor.addr.clone()],
            auth_token: Some("sesame".to_string()),
            keepalive: Some(Duration::from_millis(200)),
            rejoin_backoff: Some(Duration::from_millis(100)),
            ..ClusterOptions::default()
        },
    )
    .expect("matching tokens must connect");
    assert_eq!(remote.num_workers(), 2);

    let addr = victim.addr.clone();
    kill9(victim.pid());
    drop(victim);
    wait_for("the death to be observed", || remote.run_counters().remote_lost >= 1);

    // the address comes back with the WRONG token, stderr captured so the
    // worker-side named error can be asserted
    let evil = ListenWorker::restart_at_with(&addr, &[(AUTH_TOKEN_ENV, "imposter")], true);
    wait_for("the auth rejection", || remote.run_counters().rejoin_rejected >= 1);
    assert_eq!(remote.run_counters().rejoins, 0, "a mismatched worker must never rejoin");
    assert_eq!(remote.num_workers(), 1);

    // no hot redial loop: once rejected, the attempt counter freezes even
    // across several would-be backoff periods
    let frozen = remote.run_counters().rejoin_attempts;
    std::thread::sleep(Duration::from_millis(600));
    assert_eq!(
        remote.run_counters().rejoin_attempts,
        frozen,
        "a rejected address is never redialed"
    );

    // the worker end received the wire reject and exited with the named
    // error (not a bare EOF)
    let out = evil.wait_output();
    assert!(!out.status.success(), "rejected worker must exit with failure");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("rejected by driver") && stderr.contains("auth token mismatch"),
        "worker stderr must name the rejection: {stderr}"
    );
}
