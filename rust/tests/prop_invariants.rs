//! Property-based tests (mini-prop harness) over the coordinator's
//! invariants: RDD semantics, scheduling-independence of results, table
//! equivalence, DES sanity, and kernel math properties.

use std::sync::Arc;

use parccm::ccm::backend::{ComputeBackend, TaskArena};
use parccm::ccm::embedding::Embedding;
use parccm::ccm::knn::knn_batch;
use parccm::ccm::params::CcmParams;
use parccm::ccm::pipeline::{
    ccm_transform_rdd, f32_ulp_distance, pearson_from_sums, CcmProblem, PearsonSums,
};
use parccm::ccm::simplex::{pearson_f32, simplex_one};
use parccm::ccm::subsample::draw_samples;
use parccm::ccm::table::{DistanceTable, LibraryMask};
use parccm::engine::{Context, Deploy, EngineConfig};
use parccm::native::NativeBackend;
use parccm::util::prop::check;
use parccm::util::rng::Rng;
use parccm::{BIG, EMAX, KMAX};

fn random_series(rng: &mut Rng, n: usize) -> Vec<f32> {
    // a mildly autocorrelated bounded series
    let mut x = 0.5f64;
    (0..n)
        .map(|_| {
            x = 3.7 * x * (1.0 - x) * 0.98 + 0.01 * rng.f64();
            x as f32
        })
        .collect()
}

#[test]
fn prop_rdd_collect_equals_sequential_eval() {
    check("collect == flat sequential map", 40, |rng| {
        let n = 1 + rng.below(500);
        let parts = 1 + rng.below(12);
        let mul = (1 + rng.below(100)) as i64;
        let data: Vec<i64> = (0..n as i64).collect();
        let want: Vec<i64> = data.iter().map(|x| x * mul).collect();
        let ctx = Context::new(
            EngineConfig::new(Deploy::Local { cores: 2 }).with_default_parallelism(parts),
        );
        let got = ctx.collect(&ctx.parallelize(data).map(move |x| x * mul));
        if got == want {
            Ok(())
        } else {
            Err(format!("n={n} parts={parts}"))
        }
    });
}

#[test]
fn prop_skill_independent_of_partitioning() {
    check("partition count never changes skills", 10, |rng| {
        let series_n = 220 + rng.below(200);
        let y = random_series(rng, series_n);
        let x = random_series(rng, series_n);
        let e = 1 + rng.below(3);
        let l = 30 + rng.below(100);
        let problem = CcmProblem::new(&y, &x, e, 1, 0.0);
        let n = problem.emb.n;
        let samples = draw_samples(&Rng::new(rng.next_u64()), CcmParams::new(e, 1, l), n, 6);
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);

        let mut baseline: Option<Vec<(usize, f32)>> = None;
        for parts in [1usize, 3, 7] {
            let ctx = Context::new(
                EngineConfig::new(Deploy::Local { cores: 2 }).with_default_parallelism(parts),
            );
            let size = problem.size_bytes();
            let pb = ctx.broadcast(
                CcmProblem::new(&y, &x, e, 1, 0.0),
                size,
            );
            let mut rows = ctx.collect(&ccm_transform_rdd(
                &ctx,
                ctx.parallelize_with(samples.clone(), parts),
                &pb,
                Arc::clone(&backend),
            ));
            rows.sort_by_key(|r| r.sample_id);
            let got: Vec<(usize, f32)> = rows.iter().map(|r| (r.sample_id, r.rho)).collect();
            match &baseline {
                None => baseline = Some(got),
                Some(want) => {
                    if &got != want {
                        return Err(format!("parts={parts} changed results"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_table_query_equals_bruteforce() {
    check("indexing table == brute force k-NN", 12, |rng| {
        let n_series = 150 + rng.below(250);
        let y = random_series(rng, n_series);
        let x = random_series(rng, n_series);
        let e = 1 + rng.below(4);
        let tau = 1 + rng.below(3);
        let emb = Embedding::new(&y, e, tau);
        let targets = emb.align_targets(&x);
        let table = DistanceTable::build(&emb);
        let l = (10 + rng.below(emb.n - 12)).min(emb.n);
        let mut sample_rng = Rng::new(rng.next_u64());
        let rows = sample_rng.sample_indices(emb.n, l);
        let theiler = if rng.below(3) == 0 { rng.below(5) as f32 } else { 0.0 };

        let mut mask = LibraryMask::new();
        mask.set_from(emb.n, &rows);
        let panels = table.query_all(&rows, &mask, &targets, theiler);

        let mut lib_vecs = Vec::new();
        let mut lib_targets = Vec::new();
        let mut lib_times = Vec::new();
        for &r in &rows {
            lib_vecs.extend_from_slice(emb.point(r));
            lib_targets.push(targets[r]);
            lib_times.push(emb.time_of(r) as f32);
        }
        let pred_times: Vec<f32> = (0..emb.n).map(|i| emb.time_of(i) as f32).collect();
        let (bd, bt) =
            knn_batch(&emb.vecs, &pred_times, &lib_vecs, &lib_targets, &lib_times, theiler);
        for i in 0..emb.n * KMAX {
            if (panels.dvals[i] - bd[i]).abs() > 1e-4 || panels.tvals[i] != bt[i] {
                return Err(format!(
                    "mismatch at {i}: table ({}, {}) vs brute ({}, {}) [e={e} tau={tau} l={l} theiler={theiler}]",
                    panels.dvals[i], panels.tvals[i], bd[i], bt[i]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_truncated_table_bit_identical_to_full_and_bruteforce() {
    // The truncation contract (ISSUE 1): a truncated table — at ANY prefix
    // length, over ANY library including sparse ones that exhaust the
    // prefix and take the counted brute-force fallback — produces
    // bit-identical neighbour panels to the full table, which in turn
    // matches brute-force k-NN.
    check("truncated == full == brute force", 12, |rng| {
        let n_series = 120 + rng.below(220);
        let y = random_series(rng, n_series);
        let x = random_series(rng, n_series);
        let e = 1 + rng.below(4);
        let tau = 1 + rng.below(3);
        let emb = Embedding::new(&y, e, tau);
        let targets = emb.align_targets(&x);
        let full = DistanceTable::build(&emb);

        // library size from very sparse (fallback-heavy) to dense
        let l = (1 + rng.below(emb.n)).min(emb.n);
        let mut sample_rng = Rng::new(rng.next_u64());
        let rows = sample_rng.sample_indices(emb.n, l);
        let theiler = if rng.below(3) == 0 { rng.below(5) as f32 } else { 0.0 };
        let mut mask = LibraryMask::new();
        mask.set_from(emb.n, &rows);

        // prefix from the minimum (KMAX) to nearly full
        let prefix = KMAX + rng.below(emb.n);
        let trunc = DistanceTable::build_truncated(&emb, prefix);
        if trunc.row_len() > full.row_len() {
            return Err(format!("prefix {} exceeds full row {}", trunc.row_len(), full.row_len()));
        }

        let a = full.query_all(&rows, &mask, &targets, theiler);
        let b = trunc.query_all(&rows, &mask, &targets, theiler);
        for i in 0..emb.n * KMAX {
            if a.dvals[i].to_bits() != b.dvals[i].to_bits() || a.tvals[i] != b.tvals[i] {
                return Err(format!(
                    "truncated mismatch at {i}: full ({}, {}) vs truncated ({}, {}) \
                     [e={e} tau={tau} l={l} prefix={prefix} theiler={theiler} fallbacks={}]",
                    a.dvals[i],
                    a.tvals[i],
                    b.dvals[i],
                    b.tvals[i],
                    trunc.fallback_queries()
                ));
            }
        }

        // brute-force cross-check on the same library
        let mut lib_vecs = Vec::new();
        let mut lib_targets = Vec::new();
        let mut lib_times = Vec::new();
        for &r in &rows {
            lib_vecs.extend_from_slice(emb.point(r));
            lib_targets.push(targets[r]);
            lib_times.push(emb.time_of(r) as f32);
        }
        let pred_times: Vec<f32> = (0..emb.n).map(|i| emb.time_of(i) as f32).collect();
        let (bd, bt) =
            knn_batch(&emb.vecs, &pred_times, &lib_vecs, &lib_targets, &lib_times, theiler);
        for i in 0..emb.n * KMAX {
            if (b.dvals[i] - bd[i]).abs() > 1e-4 || b.tvals[i] != bt[i] {
                return Err(format!(
                    "truncated vs brute mismatch at {i} [e={e} tau={tau} l={l} prefix={prefix}]"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_table_rho_bit_identical_to_full() {
    // The sharding contract (ISSUE 2): splitting the table into ANY number
    // of row-range shards — full or truncated layout, dense or sparse
    // (fallback-taking) libraries — changes nothing: neighbour panels AND
    // the end-to-end cross-map skill (per-shard simplex chunks,
    // concatenated in row order, Pearson over the whole vector) are
    // bit-identical to the unsharded DistanceTable path.
    check("sharded rho == unsharded rho (bitwise)", 12, |rng| {
        let n_series = 120 + rng.below(220);
        let y = random_series(rng, n_series);
        let x = random_series(rng, n_series);
        let e = 1 + rng.below(4);
        let tau = 1 + rng.below(3);
        let emb = Embedding::new(&y, e, tau);
        let targets = emb.align_targets(&x);
        let table = if rng.below(2) == 0 {
            DistanceTable::build(&emb)
        } else {
            DistanceTable::build_truncated(&emb, KMAX + rng.below(emb.n / 2))
        };
        let num_shards = 1 + rng.below(8);
        let sharded = table.shard(num_shards);

        let l = (1 + rng.below(emb.n)).min(emb.n);
        let mut sample_rng = Rng::new(rng.next_u64());
        let rows = sample_rng.sample_indices(emb.n, l);
        let theiler = if rng.below(3) == 0 { rng.below(5) as f32 } else { 0.0 };
        let mut mask = LibraryMask::new();
        mask.set_from(emb.n, &rows);

        // panels must match bitwise
        let a = table.query_all(&rows, &mask, &targets, theiler);
        let b = sharded.query_all(&rows, &mask, &targets, theiler);
        for i in 0..emb.n * KMAX {
            if a.dvals[i].to_bits() != b.dvals[i].to_bits() || a.tvals[i] != b.tvals[i] {
                return Err(format!(
                    "panel mismatch at {i} [e={e} tau={tau} l={l} shards={num_shards} \
                     trunc={} theiler={theiler}]",
                    table.is_truncated()
                ));
            }
        }

        // end-to-end skill: unsharded tail vs concatenated shard chunks
        let backend = NativeBackend;
        let tail = backend.simplex_tail(&a, &targets, e);
        let mut arena = TaskArena::new();
        let mut preds = Vec::new();
        for shard in sharded.shards() {
            let mut chunk = Vec::new();
            backend.shard_chunk_into(shard, &targets, theiler, &rows, e, &mut arena, &mut chunk);
            preds.extend_from_slice(&chunk);
        }
        let rho = pearson_f32(&preds, &targets);
        if preds.len() != emb.n {
            return Err(format!("chunks cover {} of {} rows", preds.len(), emb.n));
        }
        if rho.to_bits() != tail.rho.to_bits() {
            return Err(format!(
                "rho mismatch: sharded {rho} vs unsharded {} \
                 [e={e} tau={tau} l={l} shards={num_shards} trunc={}]",
                tail.rho,
                table.is_truncated()
            ));
        }

        // worker-side reduce contract (this PR): reducing each shard to
        // six partial Pearson sums on the "worker" and merging driver-side
        // must land within 1 ULP of the driver-concat rho, for ANY shard
        // count, table layout, and library — and cover every row exactly
        // once.
        let partials: Vec<PearsonSums> = sharded
            .shards()
            .iter()
            .map(|shard| backend.agg_chunk_into(shard, &targets, theiler, &rows, e, &mut arena))
            .collect();
        let merged = PearsonSums::merge_all(&partials);
        if merged.n != emb.n as u64 {
            return Err(format!("merged sums cover {} of {} rows", merged.n, emb.n));
        }
        let agg_rho = pearson_from_sums(&merged);
        let ulps = f32_ulp_distance(agg_rho, rho);
        if ulps > 1 {
            return Err(format!(
                "worker-reduce rho {agg_rho} drifts {ulps} ULPs from driver-concat {rho} \
                 [e={e} tau={tau} l={l} shards={num_shards} trunc={}]",
                table.is_truncated()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_simplex_is_convex_combination() {
    check("simplex prediction within neighbour target range", 200, |rng| {
        let e = 1 + rng.below(KMAX - 1);
        let mut d = [0.0f32; KMAX];
        let mut t = [0.0f32; KMAX];
        let mut acc = 0.0f32;
        for j in 0..KMAX {
            acc += rng.f32() * 2.0;
            d[j] = acc;
            t[j] = rng.f32() * 20.0 - 10.0;
        }
        let p = simplex_one(&d, &t, e);
        let lo = t[..=e].iter().copied().fold(f32::INFINITY, f32::min);
        let hi = t[..=e].iter().copied().fold(f32::NEG_INFINITY, f32::max);
        if p >= lo - 1e-4 && p <= hi + 1e-4 {
            Ok(())
        } else {
            Err(format!("p={p} outside [{lo}, {hi}] (e={e})"))
        }
    });
}

#[test]
fn prop_pearson_bounded_and_symmetric() {
    check("|rho| <= 1 and pearson(x,y) == pearson(y,x)", 100, |rng| {
        let n = 3 + rng.below(200);
        let x: Vec<f32> = (0..n).map(|_| rng.f32() * 10.0).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.f32() * 10.0).collect();
        let a = pearson_f32(&x, &y);
        let b = pearson_f32(&y, &x);
        if a.abs() > 1.0 + 1e-5 {
            return Err(format!("|rho| > 1: {a}"));
        }
        if (a - b).abs() > 1e-6 {
            return Err(format!("asymmetric: {a} vs {b}"));
        }
        Ok(())
    });
}

#[test]
fn prop_knn_distances_sorted_and_valid() {
    check("knn output ascending, within BIG, correct count", 50, |rng| {
        let n_lib = 5 + rng.below(150);
        let n_pred = 1 + rng.below(40);
        let active = 1 + rng.below(EMAX);
        let mk = |count: usize, rng: &mut Rng| {
            let mut v = vec![0.0f32; count * EMAX];
            for i in 0..count {
                for l in 0..active {
                    v[i * EMAX + l] = rng.f32();
                }
            }
            v
        };
        let lib = mk(n_lib, rng);
        let pred = mk(n_pred, rng);
        let targets: Vec<f32> = (0..n_lib).map(|_| rng.f32()).collect();
        let lib_times: Vec<f32> = (0..n_lib).map(|i| i as f32).collect();
        let pred_times: Vec<f32> = (0..n_pred).map(|i| (i + 1000) as f32).collect();
        let (dv, _tv) = knn_batch(&pred, &pred_times, &lib, &targets, &lib_times, 0.0);
        for row in 0..n_pred {
            let r = &dv[row * KMAX..(row + 1) * KMAX];
            if !r.windows(2).all(|w| w[0] <= w[1]) {
                return Err(format!("row {row} not ascending: {r:?}"));
            }
            let real = r.iter().filter(|&&d| d < BIG / 2.0).count();
            if real != n_lib.min(KMAX) {
                return Err(format!("row {row}: {real} real neighbours, lib {n_lib}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_des_makespan_bounds() {
    // makespan must lie between total_work/cores and total_work (+overhead)
    check("DES within trivial scheduling bounds", 30, |rng| {
        let tasks = 1 + rng.below(60);
        let cores = 1 + rng.below(16);
        let log = parccm::engine::EventLog::default();
        let mut total = 0.0f64;
        log.record_job_submit(parccm::engine::metrics::JobRecord {
            job_id: 1,
            name: "j".into(),
            num_tasks: tasks,
            submit_rel: 0.0,
            finish_rel: 1.0,
            broadcast_deps: vec![],
        });
        for p in 0..tasks {
            let dur = rng.f64() * 0.01;
            total += dur;
            log.record_task(parccm::engine::metrics::TaskRecord {
                job_id: 1,
                partition: p,
                start_rel: 0.0,
                duration: dur,
                attempts: 1,
            });
        }
        let mut cfg = EngineConfig::new(Deploy::Local { cores });
        cfg.task_overhead_us = 0;
        let rep = parccm::engine::des::simulate(&log, &cfg);
        let lower = total / cores as f64 - 1e-9;
        let upper = total + 1e-9;
        if rep.sim_makespan_s >= lower && rep.sim_makespan_s <= upper {
            Ok(())
        } else {
            Err(format!(
                "makespan {} outside [{lower}, {upper}] (tasks={tasks} cores={cores})",
                rep.sim_makespan_s
            ))
        }
    });
}
