//! End-to-end tests of `parccm serve`: one daemon owning one warm remote
//! worker pool, many concurrent jobs over the v7 wire. Covered here:
//!
//! - the ISSUE's acceptance chaos schedule — two overlapping jobs on a
//!   3-listener pool, one worker killed with `kill -9` mid-run, both
//!   results byte-identical to batch references and per-job counters
//!   neither bleeding across jobs nor missing pool traffic;
//! - broadcast sharing — two concurrent jobs posing the *same* problem
//!   reuse the first tenant's resident table instead of re-shipping it
//!   (the warm pool's whole point: a pair of identical tenants ships no
//!   more broadcast traffic than one cold job).
//!
//! Worker processes are spawned exactly like `integration_remote.rs`
//! does (and like the `cluster-remote` CI job does via
//! `scripts/launch_local_cluster.sh`): `parccm worker --listen` children
//! announcing `PARCCM_WORKER_LISTENING` on stdout. Every test arms a
//! [`Watchdog`] so a hung socket fails CI fast.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use parccm::ccm::backend::ComputeBackend; // `run_counters` is a trait method
use parccm::ccm::cluster::{ClusterBackend, ClusterOptions};
use parccm::ccm::driver::{skills_to_json, Case, JobSpec, TablePolicy};
use parccm::ccm::params::Scenario;
use parccm::ccm::serve::{JobClient, ServeDaemon, ServeOptions};
use parccm::native::NativeBackend;
use parccm::util::json::Json;
use parccm::util::watchdog::Watchdog;

const TEST_TIMEOUT: Duration = Duration::from_secs(180);

fn kill9(pid: u32) {
    let status = Command::new("kill")
        .args(["-9", &pid.to_string()])
        .status()
        .expect("running kill");
    assert!(status.success(), "kill -9 {pid}");
}

/// A pre-started listen-mode worker owned by the test (see
/// `integration_remote.rs` for the full-featured variant). Killed on drop.
struct ListenWorker {
    child: Child,
    addr: String,
}

impl ListenWorker {
    fn start() -> ListenWorker {
        let mut child = Command::new(env!("CARGO_BIN_EXE_parccm"))
            .args(["worker", "--listen", "127.0.0.1:0"])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning listen worker");
        let stdout = child.stdout.take().expect("piped stdout");
        let ready = BufReader::new(stdout)
            .lines()
            .next()
            .expect("worker announces before exiting")
            .expect("readable ready line");
        let addr = ready
            .strip_prefix("PARCCM_WORKER_LISTENING ")
            .unwrap_or_else(|| panic!("unexpected ready line: {ready}"))
            .trim()
            .to_string();
        ListenWorker { child, addr }
    }

    fn pid(&self) -> u32 {
        self.child.id()
    }
}

impl Drop for ListenWorker {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn remote_pool(workers: &[ListenWorker], replicas: usize) -> Arc<ClusterBackend> {
    Arc::new(
        ClusterBackend::with_options(
            env!("CARGO_BIN_EXE_parccm"),
            ClusterOptions {
                replicas,
                workers_at: workers.iter().map(|w| w.addr.clone()).collect(),
                keepalive: Some(Duration::from_millis(500)),
                ..ClusterOptions::default()
            },
        )
        .expect("connecting the remote worker pool"),
    )
}

/// The canonical batch reference for a spec: the same `JobSpec::run` the
/// daemon executes, on the in-process backend.
fn batch_reference(spec: &JobSpec) -> String {
    skills_to_json(&spec.run(Arc::new(NativeBackend)).skills).to_string()
}

/// Poll `status` until the job leaves queued/running, then fetch its
/// dump; panics (with the daemon's error) if the job failed instead.
fn wait_fetch(client: &mut JobClient, job: u64) -> String {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let reply = client.status(job).expect("status reply");
        match reply.get("state").and_then(Json::as_str) {
            Some("queued") | Some("running") => {
                assert!(Instant::now() < deadline, "timed out waiting on job {job}");
                std::thread::sleep(Duration::from_millis(30));
            }
            Some("done") => return client.fetch(job).expect("fetching a done job"),
            other => panic!("job {job} ended in {other:?}: {reply}"),
        }
    }
}

#[test]
fn overlapping_jobs_survive_worker_kill_and_match_batch_dumps() {
    // the acceptance chaos schedule: two different jobs overlapping on a
    // 3-listener pool behind one authenticated daemon, one worker killed
    // -9 mid-run. Both jobs must finish with dumps byte-identical to
    // their batch references, and the per-job counter slices must
    // account for ALL pool broadcast/result traffic without bleeding
    // into each other (no third job id ever appears).
    let _guard = Watchdog::arm("serve_chaos_two_jobs", TEST_TIMEOUT);
    let workers = [ListenWorker::start(), ListenWorker::start(), ListenWorker::start()];
    let pool = remote_pool(&workers, 2);
    assert_eq!(pool.num_workers(), 3);

    // two distinct problems: a sharded truncated A4 and a full-table A4
    // on a different seed — different broadcasts, different task mixes
    let spec_a = JobSpec {
        case: Case::A4,
        scenario: Scenario::smoke(),
        policy: TablePolicy::TruncatedAuto,
        shards: 3,
        reduce: Default::default(),
        partial: None,
    };
    let spec_b = JobSpec {
        case: Case::A4,
        scenario: Scenario { seed: 11, ..Scenario::smoke() },
        policy: TablePolicy::Full,
        shards: 1,
        reduce: Default::default(),
        partial: None,
    };
    let ref_a = batch_reference(&spec_a);
    let ref_b = batch_reference(&spec_b);

    let daemon = ServeDaemon::start(
        Arc::clone(&pool),
        ServeOptions {
            auth_token: Some("serve-secret".to_string()),
            max_concurrent_jobs: 2,
            ..ServeOptions::default()
        },
    )
    .expect("starting the serve daemon");

    let mut c1 = JobClient::connect(daemon.addr(), Some("serve-secret")).expect("client 1");
    let mut c2 = JobClient::connect(daemon.addr(), Some("serve-secret")).expect("client 2");
    let job_a = c1.submit(&spec_a).expect("submitting job A");
    let job_b = c2.submit(&spec_b).expect("submitting job B");
    assert_ne!(job_a, job_b);

    // kill one listener while the jobs are (very likely) mid-run; the
    // dump assertions below hold either way — the pool requeues the
    // victim's tasks onto the survivors (replicas 2 keeps sharded
    // payloads resident somewhere)
    let victim = workers[0].pid();
    let killer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(100));
        kill9(victim);
    });

    let dump_a = wait_fetch(&mut c1, job_a);
    let dump_b = wait_fetch(&mut c2, job_b);
    killer.join().unwrap();

    assert_eq!(dump_a, ref_a, "job A must be byte-identical to its batch reference");
    assert_eq!(dump_b, ref_b, "job B must be byte-identical to its batch reference");

    // counter attribution: each job saw its own traffic, nothing else
    // did, and the slices sum to the pool totals exactly — repair
    // traffic from the kill is pool-level and deliberately outside the
    // per-job slices
    let tallies = pool.job_tallies();
    assert_eq!(
        tallies.iter().map(|&(j, _)| j).collect::<Vec<_>>(),
        vec![job_a, job_b],
        "exactly the two submitted jobs carry tallies"
    );
    let ta = pool.job_tally(job_a);
    let tb = pool.job_tally(job_b);
    assert!(ta.tasks > 0 && tb.tasks > 0, "both jobs computed on the pool");
    assert!(ta.broadcast_ship_bytes > 0 && tb.broadcast_ship_bytes > 0);
    let counters = pool.run_counters();
    assert_eq!(ta.broadcast_ships + tb.broadcast_ships, counters.broadcast_ships);
    assert_eq!(
        ta.broadcast_ship_bytes + tb.broadcast_ship_bytes,
        counters.broadcast_ship_bytes
    );
    assert_eq!(
        ta.result_ingress_bytes + tb.result_ingress_bytes,
        counters.result_ingress_bytes
    );
    assert_eq!(counters.respawns, 0, "remote workers are never respawned");
    assert!(pool.num_workers() >= 2, "at most the killed worker may be gone");

    let mut daemon = daemon;
    daemon.shutdown();
    assert_eq!(daemon.tracker().jobs_served(), 2);
}

#[test]
fn concurrent_identical_jobs_share_the_resident_broadcast() {
    // the warm pool's multi-tenant dividend: two concurrent jobs posing
    // the SAME problem reuse the driver payload cache and the workers'
    // resident copies, so the pair ships no more broadcast traffic than
    // one cold job. Phase 1 measures a solo job's ships; phase 2 runs
    // two identical jobs overlapped (the solo job's eviction made the
    // pool cold again in between) and must not exceed that solo budget.
    let _guard = Watchdog::arm("serve_shared_broadcast", TEST_TIMEOUT);
    let workers = [ListenWorker::start(), ListenWorker::start(), ListenWorker::start()];
    let pool = remote_pool(&workers, 1);

    // big enough that a job runs far longer than the ~ms it takes the
    // second runner thread to reach its broadcast: the overlap the
    // sharing depends on is structural, not a lucky race
    let spec = JobSpec {
        case: Case::A4,
        scenario: Scenario {
            series_len: 400,
            r: 16,
            ls: vec![60, 120, 180, 240],
            es: vec![2],
            taus: vec![1],
            theiler: 0,
            seed: 7,
            partitions: 6,
        },
        policy: TablePolicy::TruncatedAuto,
        shards: 1,
        reduce: Default::default(),
        partial: None,
    };
    let reference = batch_reference(&spec);

    let daemon = ServeDaemon::start(
        Arc::clone(&pool),
        ServeOptions { max_concurrent_jobs: 2, ..ServeOptions::default() },
    )
    .expect("starting the serve daemon");
    let mut client = JobClient::connect(daemon.addr(), None).expect("job client");

    // phase 1: one cold job alone — its ship count is the budget
    let solo = client.submit(&spec).expect("submitting the solo job");
    assert_eq!(wait_fetch(&mut client, solo), reference);
    let solo_ships = pool.run_counters().broadcast_ships;
    assert!(solo_ships > 0, "a cold job must ship its table");
    assert_eq!(pool.cached_payloads(), 0, "solo harvest evicts the cache");

    // phase 2: two identical tenants overlapped on the (again cold) pool
    let t1 = client.submit(&spec).expect("submitting tenant 1");
    let t2 = client.submit(&spec).expect("submitting tenant 2");
    let d1 = wait_fetch(&mut client, t1);
    let d2 = wait_fetch(&mut client, t2);
    assert_eq!(d1, reference, "tenant 1 byte-identical to batch");
    assert_eq!(d2, reference, "tenant 2 byte-identical to batch");

    let pair_ships = pool.run_counters().broadcast_ships - solo_ships;
    assert!(
        pair_ships <= solo_ships,
        "two tenants sharing one problem must not ship more than one cold \
         job did (pair {pair_ships} vs solo {solo_ships}); without the \
         job-refcounted payload cache this would be ~2x"
    );
    let (ta, tb) = (pool.job_tally(t1), pool.job_tally(t2));
    assert!(ta.tasks > 0 && tb.tasks > 0, "both tenants computed on the pool");
    assert_eq!(pool.cached_payloads(), 0, "last tenant out frees the shared entry");

    let mut daemon = daemon;
    daemon.shutdown();
    assert_eq!(daemon.tracker().jobs_served(), 3);
}
